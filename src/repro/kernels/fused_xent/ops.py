"""Public fused-xent op: jit wrapper with padding + interpret switch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.fused_xent.kernel import fused_xent_kernel


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fused_softmax_xent(
    x,
    w,
    labels,
    *,
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool | None = None,
):
    """x: (T,d); w: (d,V); labels: (T,) -> (T,) per-token loss.

    Pads T to a token-block multiple (padded rows are trimmed). The vocab
    dim is never padded — block_v is shrunk to the largest divisor of V
    at most block_v, so no fake logits enter the logsumexp.
    """
    if interpret is None:
        interpret = default_interpret()
    T, d = x.shape
    V = w.shape[-1]
    bt = min(block_t, T)
    # choose a vocab block that divides V to avoid padding the vocab dim
    bv = min(block_v, V)
    while V % bv:
        bv -= 1
    pad_t = (-T) % bt
    if pad_t:
        x = jnp.pad(x, ((0, pad_t), (0, 0)))
        labels = jnp.pad(labels, ((0, pad_t),))
    loss = fused_xent_kernel(
        x, w, labels, block_t=bt, block_v=bv, interpret=interpret
    )
    return loss[:T]
