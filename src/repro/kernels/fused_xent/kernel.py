"""Fused softmax cross-entropy Pallas TPU kernel.

For 100k+ vocabularies the (B*S, V) logit matrix dominates HBM traffic.
This kernel never materialises it: grid (token blocks, vocab blocks) with
the vocab dim innermost/sequential; each step computes a (bt, bv) logit tile
on the MXU (x_tile @ w_tile), folds it into online logsumexp accumulators,
and extracts the gold logit when the label falls inside the current tile.
Peak VMEM = bt*d + d*bv + bt*bv fp32 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _xent_kernel(
    x_ref,       # (bt, d)
    w_ref,       # (d, bv)
    lab_ref,     # (bt, 1) int32
    loss_ref,    # (bt, 1) out
    m_ref,       # scratch (bt, 1)
    l_ref,       # scratch (bt, 1)
    gold_ref,    # scratch (bt, 1)
    *,
    block_v: int,
    num_v_blocks: int,
):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    logits = x @ w  # (bt, bv)

    v_start = iv * block_v
    labels = lab_ref[...]  # (bt, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + v_start
    is_gold = col == labels  # (bt, bv)
    gold_ref[...] = gold_ref[...] + jnp.sum(
        jnp.where(is_gold, logits, 0.0), axis=-1, keepdims=True
    )

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_cur) + jnp.sum(
        jnp.exp(logits - m_cur), axis=-1, keepdims=True
    )
    m_ref[...] = m_cur

    @pl.when(iv == num_v_blocks - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        loss_ref[...] = (lse - gold_ref[...]).astype(loss_ref.dtype)


def fused_xent_kernel(
    x,
    w,
    labels,
    *,
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool = False,
):
    """x: (T,d); w: (d,V); labels: (T,) int32 -> per-token loss (T,)."""
    T, d = x.shape
    V = w.shape[-1]
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    assert T % block_t == 0, (T, block_t)
    assert V % block_v == 0, (V, block_v)
    nt = T // block_t
    nv = V // block_v

    kernel = functools.partial(_xent_kernel, block_v=block_v, num_v_blocks=nv)
    loss = pl.pallas_call(
        kernel,
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda it, iv: (it, 0)),
            pl.BlockSpec((d, block_v), lambda it, iv: (0, iv)),
            pl.BlockSpec((block_t, 1), lambda it, iv: (it, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, 1), lambda it, iv: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, labels.reshape(T, 1).astype(jnp.int32))
    return loss[:, 0]
