"""Pure-jnp oracle: softmax cross-entropy from hidden states."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent_ref(x, w, labels):
    """x: (T,d); w: (d,V); labels: (T,) -> per-token loss (T,)."""
    logits = (x.astype(jnp.float32)) @ w.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - gold
