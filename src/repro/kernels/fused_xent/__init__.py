"""Fused softmax-cross-entropy kernel package."""
from repro.kernels.fused_xent.ops import fused_softmax_xent

__all__ = ["fused_softmax_xent"]
