"""Pallas TPU kernels for the compute hot-spots.

Each kernel package has the same three-file layout (the authoring contract
is documented end-to-end in docs/KERNELS.md):

  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper: padding, the interpret/backed dispatch
              and (where the op is differentiable) the custom_vjp seam
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Importing this package never touches an accelerator: `default_interpret`
below is the single interpret-mode guard every ops wrapper consults, and
it only *reads* ``jax.default_backend()`` — no Pallas lowering, no device
compilation happens at import time, so importing kernels on a no-GPU/TPU
box cannot hard-fail (tests/test_recurrent_scan.py smoke-tests this).
Kernels compile lazily, on the first call of an op.
"""
import jax


def default_interpret() -> bool:
    """Whether Pallas calls should default to interpreter mode.

    True everywhere except on a real TPU backend: the kernels in this
    package target TPU, and the Pallas interpreter is the only way to run
    them elsewhere (CI runs the parity sweeps through it).  Ops that have
    a pure-XLA fallback (`recurrent_scan`) use this guard to pick that
    fast path instead of interpreting.  Callers can always override per
    call via their ``interpret=`` keyword.
    """
    return jax.default_backend() != "tpu"


from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402
from repro.kernels.fused_xent.ops import fused_softmax_xent  # noqa: E402
from repro.kernels.recurrent_scan.ops import linear_recurrent_scan  # noqa: E402
from repro.kernels.selective_scan.ops import selective_scan  # noqa: E402

__all__ = [
    "default_interpret",
    "flash_attention",
    "fused_softmax_xent",
    "linear_recurrent_scan",
    "selective_scan",
]
