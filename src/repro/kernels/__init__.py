"""Pallas TPU kernels for the compute hot-spots.

Each kernel package has:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True on CPU for validation)
  ref.py    — pure-jnp oracle used by the allclose test sweeps
"""
