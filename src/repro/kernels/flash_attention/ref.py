"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Hq,S,hd); k,v: (B,Hkv,S,hd) with Hq % Hkv == 0 -> (B,Hq,S,hd)."""
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    n_rep = Hq // Hkv
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=1)
        v = jnp.repeat(v, n_rep, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
