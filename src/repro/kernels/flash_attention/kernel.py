"""Flash attention Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dim is the
innermost (sequential) dim, so the online-softmax accumulators live in VMEM
scratch across kv iterations. BlockSpecs tile Q/K/V into
(block_q, head_dim) / (block_kv, head_dim) VMEM tiles; block sizes default
to 128 to align with the MXU's 128x128 systolic array. GQA is handled in the
K/V index_map (query head h reads kv head h // n_rep); causal and
sliding-window masking are applied from program ids. Fully-masked kv blocks
are skipped with pl.when (structural zero-work, the TPU analogue of the CUDA
kernel's early-exit over tiles).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
    causal: bool,
    window: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv

    # Structural skip: blocks entirely above the causal diagonal or entirely
    # outside the sliding window contribute nothing.
    live = jnp.asarray(True)
    if causal:
        live = kv_start <= q_start + block_q - 1
    if window:
        live = jnp.logical_and(live, kv_start + block_kv - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        s = q @ k.T  # (bq, bkv)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        if causal:
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        if window:
            s = jnp.where(k_pos > q_pos - window, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)  # (bq, 1)
        p = jnp.exp(s - m_cur)  # (bq, bkv)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        acc_ref[...] = acc_ref[...] * alpha + p @ v

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        norm = jnp.maximum(l_ref[...], 1e-30)  # (bq, 1)
        o_ref[0, 0] = (acc_ref[...] / norm).astype(o_ref.dtype)


def flash_attention_kernel(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
):
    """q: (B, Hq, S, hd); k, v: (B, Hkv, S, hd). Returns (B, Hq, S, hd).

    S must be divisible by the block sizes (ops.py pads otherwise).
    """
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    n_rep = Hq // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    nq = S // block_q
    nkv = S // block_kv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        causal=causal,
        window=window,
    )

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, iq, ik: (b, h // n_rep, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, iq, ik: (b, h // n_rep, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
