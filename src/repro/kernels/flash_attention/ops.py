"""Public flash-attention op: jit wrapper with padding + interpret switch.

Differentiable via jax.custom_vjp: the forward pass runs the Pallas kernel,
the backward pass differentiates the pure-jnp oracle (on a real TPU the
backward would be its own kernel; the custom_vjp seam is where it plugs in).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv", "interpret")
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
):
    """q: (B,Hq,S,hd); k,v: (B,Hkv,S,hd) -> (B,Hq,S,hd).

    Pads S up to a block multiple (padded queries are trimmed; padded keys are
    masked out by the causal mask since they sit at positions > any real
    query; for non-causal use the ref path).
    """
    if interpret is None:
        interpret = default_interpret()

    @functools.partial(jax.custom_vjp)
    def _op(q, k, v):
        return _fwd_impl(q, k, v)

    def _fwd_impl(q, k, v):
        B, Hq, S, hd = q.shape
        bq = min(block_q, S)
        bkv = min(block_kv, S)
        pad = (-S) % max(bq, bkv)
        if pad:
            zp = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
            q, k, v = zp(q), zp(k), zp(v)
        out = flash_attention_kernel(
            q,
            k,
            v,
            causal=causal,
            window=window,
            block_q=bq,
            block_kv=bkv,
            interpret=interpret,
        )
        return out[:, :, :S]

    def _fwd(q, k, v):
        return _fwd_impl(q, k, v), (q, k, v)

    def _bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q, k, v: attention_ref(q, k, v, causal=causal, window=window),
            q,
            k,
            v,
        )
        return vjp(g)

    _op.defvjp(_fwd, _bwd)
    return _op(q, k, v)
