"""Public gated-linear-recurrence op: fused RNN unroll with a custom VJP.

``linear_recurrent_scan(a, b, h0, reset)`` evaluates

    h_t = a_t * (1 - reset_t) * h_{t-1} + b_t

over a leading time axis — the whole-trajectory unroll of any linear
recurrent core (`repro.nn.LinearScannedRNN`), with episode-boundary resets
folded into the decay coefficient *inside* the fused scan rather than
masked onto the carry between python-level scan steps.

Three execution paths behind one signature:

* **TPU (default on TPU backends)** — the blocked associative-scan Pallas
  kernel (`kernel.py`), compiled;
* **non-TPU default** — the same log-depth algorithm as one fused XLA
  ``lax.associative_scan`` (no Pallas involved), so CPU/GPU boxes get the
  parallel-scan throughput win without the Pallas interpreter;
* **``interpret=True``** — the Pallas kernel through the interpreter,
  for CI parity sweeps against the sequential oracle (`ref.py`).

Differentiable via ``jax.custom_vjp``: the adjoint recurrence
``lam_t = g_t + a_{t+1} * lam_{t+1}`` is itself a first-order linear
recurrence, so the backward pass re-runs the *same* fused forward on
time-reversed arrays (on TPU the backward hits the same kernel).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.recurrent_scan.kernel import _combine, linear_scan_kernel


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def linear_recurrent_scan(
    a,
    b,
    h0,
    reset=None,
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """a, b: (T, ..., H); h0: (..., H); reset: (T, ...) bools -> hs (T, ..., H).

    Inclusive outputs: ``hs[t]`` is the state *after* absorbing row ``t``
    (the final carry is ``hs[-1]``).  ``reset`` rows restart the recurrence
    from ``b_t`` alone by zeroing that row's decay — the fused form of the
    memory-core protocol's `reset_carry` rule.  ``interpret=None`` picks
    the compiled Pallas kernel on TPU and the pure-XLA associative scan
    elsewhere; ``interpret=True`` forces the kernel through the Pallas
    interpreter (validation only — far too slow for training).
    """
    T = a.shape[0]
    if reset is None:
        r = jnp.zeros(a.shape[:-1] + (1,), a.dtype)
    else:
        r = reset.astype(a.dtype)[..., None]
    if interpret is None:
        use_pallas, pallas_interpret = not default_interpret(), False
    else:
        use_pallas, pallas_interpret = True, interpret

    def _fwd_impl(a, b, r, h0):
        """Dispatch one fused forward scan (shared by forward and backward)."""
        if not use_pallas:
            a_eff = a * (1.0 - r)
            A, B = jax.lax.associative_scan(_combine, (a_eff, b), axis=0)
            return A * h0[None] + B
        batch = a.shape[1:]
        D = math.prod(batch)
        rb = jnp.broadcast_to(r, a.shape)
        a2, b2, r2 = (t.reshape(T, D) for t in (a, b, rb))
        h2 = h0.reshape(1, D)
        bd = min(block_d, _round_up(D, 128))
        ck = min(chunk, _round_up(T, 8))
        pad_t, pad_d = (-T) % ck, (-D) % bd
        if pad_t or pad_d:
            # zero padding is inert (a=0, b=0 holds the padded lanes at 0)
            zp = lambda t: jnp.pad(t, ((0, pad_t), (0, pad_d)))
            a2, b2, r2 = zp(a2), zp(b2), zp(r2)
            h2 = jnp.pad(h2, ((0, 0), (0, pad_d)))
        hs = linear_scan_kernel(
            a2, b2, r2, h2, block_d=bd, chunk=ck, interpret=pallas_interpret
        )
        return hs[:T, :D].reshape((T, *batch))

    @jax.custom_vjp
    def _op(a, b, r, h0):
        return _fwd_impl(a, b, r, h0)

    def _fwd(a, b, r, h0):
        hs = _fwd_impl(a, b, r, h0)
        return hs, (a, b, r, h0, hs)

    def _bwd(res, g):
        a, b, r, h0, hs = res
        a_eff = a * (1.0 - r)
        # The adjoint lam_t = g_t + a_eff_{t+1} * lam_{t+1} is the same
        # recurrence on time-reversed arrays with the decay shifted one
        # step, so the backward re-uses the fused forward path.
        a_shift = jnp.concatenate([a_eff[1:], jnp.zeros_like(a_eff[:1])], 0)
        lam = jnp.flip(
            _fwd_impl(
                jnp.flip(a_shift, 0), jnp.flip(g, 0),
                jnp.zeros_like(r), jnp.zeros_like(h0),
            ),
            0,
        )
        h_prev = jnp.concatenate([h0[None], hs[:-1]], 0)
        da_eff = lam * h_prev
        dr = -jnp.sum(da_eff * a, axis=-1, keepdims=True)
        return da_eff * (1.0 - r), lam, dr, a_eff[0] * lam[0]

    _op.defvjp(_fwd, _bwd)
    return _op(a, b, r, h0)
