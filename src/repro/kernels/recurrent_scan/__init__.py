"""Gated-linear-recurrence (fused RNN unroll) kernel package."""
from repro.kernels.recurrent_scan.ops import linear_recurrent_scan

__all__ = ["linear_recurrent_scan"]
