"""Pure-jnp oracle for the gated linear recurrence.

The sequential `lax.scan` definition of

    h_t = a_t * h_{t-1} + b_t        (elementwise over the feature dim)

used by the allclose test sweeps as ground truth for both the Pallas
kernel and the XLA associative-scan fast path in ``ops.py``.  An optional
``reset`` mask folds into the decay coefficient exactly the way the fused
paths do it (``a_t <- a_t * (1 - reset_t)``), so the oracle pins the
reset-in-kernel semantics too, not just the recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_recurrence_ref(a, b, h0, reset=None):
    """Sequential oracle: ``a, b: (T, ..., H); h0: (..., H) -> hs (T, ..., H)``.

    ``reset`` (optional ``(T, ...)`` booleans) zeroes the incoming hidden
    state at marked rows by zeroing that row's decay — the same fold the
    fused implementations apply, so all three paths share one semantics.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if reset is not None:
        a = a * (1.0 - reset[..., None].astype(jnp.float32))

    def step(h, ab_t):
        a_t, b_t = ab_t
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a, b))
    return hs
