"""Blocked associative-scan Pallas TPU kernel for the gated linear recurrence.

Fuses the RNN unroll ``h_t = a_t * h_{t-1} + b_t`` (elementwise over a
flattened feature dim) into one kernel.  The first-order recurrence is
associative under the affine-composition combine

    (a1, b1) (+) (a2, b2) = (a2 * a1, a2 * b1 + b2)

so each (time chunk, feature block) tile runs a *log-depth*
``lax.associative_scan`` over its chunk instead of a sequential loop, then
splices the chunk onto the running carry with one multiply-add: the
inclusive prefix ``(A_t, B_t)`` of a chunk maps the incoming hidden state
straight to ``h_t = A_t * h_in + B_t``.

Grid is (feature blocks, seq chunks) with the seq dim innermost/sequential;
the carry lives in VMEM scratch and persists across chunks (the
selective_scan layout).  Episode-boundary resets arrive as a mask operand
and fold into the decay coefficient *inside* the kernel body
(``a_t <- a_t * (1 - reset_t)``): a reset row is simply a row whose decay
is zero, so no separate carry-masking pass exists at all — this is how the
memory-core protocol's ``reset_carry`` rule moves into the kernel.

block_d is chosen a multiple of 128 (lane width); chunk rides the sublane
dim, so (chunk, block_d) tiles satisfy the f32 (8, 128) minimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine(left, right):
    """Affine composition: apply ``left`` first, then ``right``."""
    a1, b1 = left
    a2, b2 = right
    return a2 * a1, a2 * b1 + b2


def _scan_kernel(
    a_ref,      # (chunk, bd)
    b_ref,      # (chunk, bd)
    r_ref,      # (chunk, bd) — reset mask, broadcast over features
    h0_ref,     # (1, bd)
    out_ref,    # (chunk, bd)
    h_ref,      # scratch (1, bd) fp32 — carry across seq chunks
    *,
    chunk: int,
):
    """One (time chunk, feature block) tile of the blocked scan."""
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # reset_carry masking, in-kernel: zero the decay where a row opens a
    # new episode, so the recurrence restarts from b_t alone
    a = a * (1.0 - r_ref[...].astype(jnp.float32))
    # log-depth inclusive prefix of the affine maps within the chunk
    A, B = jax.lax.associative_scan(_combine, (a, b), axis=0)
    h = A * h_ref[...] + B          # splice onto the carried-in state
    out_ref[...] = h.astype(out_ref.dtype)
    h_ref[...] = h[chunk - 1 : chunk]


def linear_scan_kernel(
    a,
    b,
    reset,
    h0,
    *,
    block_d: int = 512,
    chunk: int = 128,
    interpret: bool = False,
):
    """a, b, reset: (T, D); h0: (1, D) -> hs (T, D).

    Caller pads T to a chunk multiple and D to a block_d multiple
    (zero rows/columns are inert: a=0, b=0 holds h at 0).
    """
    T, D = a.shape
    block_d = min(block_d, D)
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    assert D % block_d == 0, (D, block_d)
    nd = D // block_d
    nc = T // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(nd, nc),
        in_specs=[
            pl.BlockSpec((chunk, block_d), lambda id_, ic: (ic, id_)),
            pl.BlockSpec((chunk, block_d), lambda id_, ic: (ic, id_)),
            pl.BlockSpec((chunk, block_d), lambda id_, ic: (ic, id_)),
            pl.BlockSpec((1, block_d), lambda id_, ic: (0, id_)),
        ],
        out_specs=pl.BlockSpec((chunk, block_d), lambda id_, ic: (ic, id_)),
        out_shape=jax.ShapeDtypeStruct((T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, b, reset, h0)
