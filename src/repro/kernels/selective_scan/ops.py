"""Public selective-scan op: jit wrapper with padding + interpret switch.

Differentiable via jax.custom_vjp (kernel forward, oracle backward — the
same seam a TPU backward kernel would use).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.selective_scan.kernel import selective_scan_kernel
from repro.kernels.selective_scan.ref import selective_scan_ref


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(
    x,
    delta,
    A,
    B,
    C,
    D,
    *,
    block_d: int = 256,
    chunk: int = 64,
    interpret: bool | None = None,
):
    """Mamba1 scan; pads S to a chunk multiple (delta=0 padding is inert).

    Returns (y: (b,S,di), h_final: (b,di,N) fp32).
    """
    if interpret is None:
        interpret = default_interpret()
    b, S, di = x.shape
    c = min(chunk, S)

    @jax.custom_vjp
    def _op(x, delta, A, B, C, D):
        return _fwd_impl(x, delta, A, B, C, D)

    def _fwd_impl(x, delta, A, B, C, D):
        pad = (-S) % c
        if pad:
            zp2 = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
            x_p, delta_p, B_p, C_p = zp2(x), zp2(delta), zp2(B), zp2(C)
        else:
            x_p, delta_p, B_p, C_p = x, delta, B, C
        y, h_final = selective_scan_kernel(
            x_p, delta_p, A, B_p, C_p, D, block_d=block_d, chunk=c,
            interpret=interpret,
        )
        return y[:, :S], h_final

    def _fwd(x, delta, A, B, C, D):
        return _fwd_impl(x, delta, A, B, C, D), (x, delta, A, B, C, D)

    def _bwd(res, g):
        _, vjp = jax.vjp(selective_scan_ref, *res)
        return vjp(g)

    _op.defvjp(_fwd, _bwd)
    return _op(x, delta, A, B, C, D)
