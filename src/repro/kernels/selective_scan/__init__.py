"""Selective-scan (Mamba1 SSM recurrence) kernel package."""
from repro.kernels.selective_scan.ops import selective_scan

__all__ = ["selective_scan"]
