"""Pure-jnp oracle for the mamba1 selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, delta, A, B, C, D):
    """x, delta: (b,S,di); A: (di,N); B,C: (b,S,N); D: (di,) -> y (b,S,di).

    h_t = exp(delta_t A) h_{t-1} + (delta_t x_t) outer B_t
    y_t = h_t . C_t + D x_t
    """
    x32 = x.astype(jnp.float32)
    delta = delta.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)

    def step(h, inp):
        x_t, d_t, B_t, C_t = inp
        h = jnp.exp(d_t[..., None] * A) * h + (d_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    b, S, di = x.shape
    h0 = jnp.zeros((b, di, A.shape[-1]), jnp.float32)
    xs = (x32.swapaxes(0, 1), delta.swapaxes(0, 1), B.swapaxes(0, 1), C.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + x32 * D
    return y.astype(x.dtype), h
