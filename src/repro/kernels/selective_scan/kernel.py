"""Mamba1 selective-scan Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of a warp-level scan with
state in registers, the grid is (batch, d_inner blocks, seq chunks) with the
seq dim innermost/sequential; the recurrent state (block_d, N) lives in VMEM
scratch and persists across seq chunks. Each invocation streams one
(chunk, block_d) tile of x/delta and one (chunk, N) tile of B/C from HBM into
VMEM and runs the recurrence with a fori_loop over the chunk.

block_d is chosen a multiple of 128 (lane width); N (the SSM state, 16 for
mamba1) rides in the sublane dim of the (block_d, N) state tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref,      # (1, chunk, bd)
    dt_ref,     # (1, chunk, bd)
    B_ref,      # (1, chunk, N)
    C_ref,      # (1, chunk, N)
    A_ref,      # (bd, N)
    D_ref,      # (1, bd)
    y_ref,      # (1, chunk, bd)
    hout_ref,   # (1, bd, N) — final state, written on the last chunk
    h_ref,      # scratch (bd, N) fp32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...]            # (bd, N)
    x = x_ref[0].astype(jnp.float32)    # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)  # (chunk, bd)
    Bm = B_ref[0].astype(jnp.float32)   # (chunk, N)
    Cm = C_ref[0].astype(jnp.float32)   # (chunk, N)

    def step(t, carry):
        h, ys = carry
        d_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)  # (1, bd)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)   # (1, bd)
        B_t = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)  # (1, N)
        C_t = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)  # (1, N)
        dA = jnp.exp(d_t.T * A)                          # (bd, N)
        h = dA * h + (d_t * x_t).T * B_t                 # (bd, N)
        y_t = h @ C_t.T                                  # (bd, 1)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_t.T, t, 0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_ref[...] = h
    y_ref[0] = (ys + x * D_ref[0]).astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _emit_state():
        hout_ref[0] = h


def selective_scan_kernel(
    x,
    delta,
    A,
    B,
    C,
    D,
    *,
    block_d: int = 256,
    chunk: int = 64,
    interpret: bool = False,
):
    """x, delta: (b,S,di); A: (di,N); B,C: (b,S,N); D: (di,) -> y (b,S,di)."""
    b, S, di = x.shape
    N = A.shape[-1]
    block_d = min(block_d, di)
    chunk = min(chunk, S)
    assert di % block_d == 0, (di, block_d)
    assert S % chunk == 0, (S, chunk)
    nd = di // block_d
    nc = S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, num_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(b, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, N), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((block_d, N), lambda ib, id_, ic: (id_, 0)),
            pl.BlockSpec((1, block_d), lambda ib, id_, ic: (0, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, block_d, N), lambda ib, id_, ic: (ib, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, di), x.dtype),
            jax.ShapeDtypeStruct((b, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, delta, B, C, A, D.reshape(1, di))
