from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adam,
    sgd,
    rmsprop,
    chain,
    clip_by_global_norm,
    scale,
    apply_updates,
    global_norm,
)
from repro.optim.schedules import (
    constant,
    linear_warmup_cosine_decay,
    linear_schedule,
)

__all__ = [
    "Optimizer",
    "adamw",
    "adam",
    "sgd",
    "rmsprop",
    "chain",
    "clip_by_global_norm",
    "scale",
    "apply_updates",
    "global_norm",
    "constant",
    "linear_warmup_cosine_decay",
    "linear_schedule",
]
