"""Gradient-transformation optimizers (optax-style, no optax offline).

An Optimizer is a pair of pure functions:

  init(params) -> state
  update(grads, state, params) -> (updates, new_state)

`apply_updates(params, updates)` adds the updates. All transforms are
pytree-polymorphic so they work for both the MARL agent networks and the
sharded LM parameter trees (optimizer state inherits the param shardings
through GSPMD since it is elementwise over params).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence, Union

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


ScalarOrSchedule = Union[float, Callable]


def _lr(lr: ScalarOrSchedule, count):
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates
    )


def scale(factor: float) -> Optimizer:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: object
    nu: object


def adamw(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mu_dtype=None,
) -> Optimizer:
    def init(params):
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=mu_dtype or p.dtype), params
        )
        nu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
        return AdamState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params):
        count = state.count + 1
        lr = _lr(learning_rate, count)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1**count.astype(jnp.float32)
        bc2 = 1 - b2**count.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v / bc2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(count=count, mu=mu, nu=nu)

    return Optimizer(init, update)


def adam(learning_rate: ScalarOrSchedule, **kw) -> Optimizer:
    return adamw(learning_rate, weight_decay=0.0, **kw)


class SgdState(NamedTuple):
    count: jnp.ndarray
    momentum: object


def sgd(learning_rate: ScalarOrSchedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = (
            jax.tree_util.tree_map(jnp.zeros_like, params) if momentum else ()
        )
        return SgdState(count=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        lr = _lr(learning_rate, count)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state.momentum, grads
            )
            updates = jax.tree_util.tree_map(lambda m: -lr * m, mom)
            return updates, SgdState(count, mom)
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, SgdState(count, ())

    return Optimizer(init, update)


class RmspropState(NamedTuple):
    count: jnp.ndarray
    nu: object


def rmsprop(
    learning_rate: ScalarOrSchedule, decay: float = 0.9, eps: float = 1e-8
) -> Optimizer:
    def init(params):
        nu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params
        )
        return RmspropState(count=jnp.zeros((), jnp.int32), nu=nu)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        lr = _lr(learning_rate, count)
        nu = jax.tree_util.tree_map(
            lambda v, g: decay * v + (1 - decay) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        updates = jax.tree_util.tree_map(
            lambda g, v: (-lr * g.astype(jnp.float32) / (jnp.sqrt(v) + eps)).astype(
                g.dtype
            ),
            grads,
            nu,
        )
        return updates, RmspropState(count, nu)

    return Optimizer(init, update)


def chain(*transforms: Sequence[Optimizer]) -> Optimizer:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Optimizer(init, update)
