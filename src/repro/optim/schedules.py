"""Learning-rate schedules (scalar step -> scalar lr, jax-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def schedule(step):
        return jnp.asarray(value, jnp.float32) + 0.0 * step

    return schedule


def linear_schedule(init_value: float, end_value: float, transition_steps: int):
    def schedule(step):
        frac = jnp.clip(step / max(transition_steps, 1), 0.0, 1.0)
        return init_value + frac * (end_value - init_value)

    return schedule


def linear_warmup_cosine_decay(
    peak_value: float,
    warmup_steps: int,
    decay_steps: int,
    end_value: float = 0.0,
):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_value * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / jnp.maximum(decay_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = end_value + 0.5 * (peak_value - end_value) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
