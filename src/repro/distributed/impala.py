"""IMPALA-style async actor/learner training as one fused jit program.

Everything before this module is lockstep: anakin interleaves acting and
learning in one scan, so the learner waits for every env step and the
actors wait for every update. `make_async` splits the two roles the way
the paper's Launchpad graphs (and marl-jax) do — N *actor replicas* roll
out trajectory chunks with a (possibly stale) **snapshot** of the learner
params and push them into a shared device-resident trajectory queue
(`repro.core.buffer.QueueState`); the *learner* pops chunks, feeds them
through the system's ordinary dataset protocol (`observe` + the
``can_sample``-gated update) and refreshes the actors' snapshot every
``param_sync_every`` ticks.  The whole graph still compiles to a single
``lax.scan`` under one jit — deterministic, reproducible, and the actor
axis is vmapped so throughput scales with actor count instead of being
bound by the lockstep scan (the `async_actors` rung of BENCH_speed).

The bounded-staleness contract (pinned by ``tests/test_async.py``):

* staleness 0 — with ``num_actors=1`` and ``param_sync_every=1`` the
  program replays anakin's exact acting stream (`_act_phase` with the
  same key threading) and update sequence (the shipped per-row update
  keys), **bitwise**, for all three experience regimes;
* staleness bounded — a chunk collected under snapshot ``s`` is consumed
  after at most ``param_sync_every * num_actors * U`` learner updates
  (``U`` rows per chunk, one potential update per row), and every
  consumed chunk's actual staleness (learner updates since its snapshot)
  is surfaced in the per-tick telemetry;
* off-policy correction — on-policy families consume stale chunks with
  V-trace importance weighting (``PPOConfig.use_vtrace``, math in
  `repro.systems.vtrace`); replay-regime systems consume directly (their
  update is already off-policy).

Device placement rides the `repro.distributed.sharding` seam: actor-state
leaves are annotated with the ``"actors"`` logical axis, so running the
program under ``enter_mesh`` spreads actor replicas across the mesh data
axis while the learner/queue stay replicated (no-op without a mesh — see
docs/DISTRIBUTED.md).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.buffer import (
    QueueState,
    RolloutState,
    SeqBufferState,
    queue_init,
    queue_pop,
    queue_push,
)
from repro.core.system import (
    System,
    _act_phase,
    _do_updates,
    _tap_body,
    _training_env,
    _unalias,
    init_system_state,
)
from repro.core.types import TrainState
from repro.distributed.sharding import with_logical_constraint


class ActorState(NamedTuple):
    """One actor replica's private state (leaves carry a ``(num_actors,)``
    lane axis inside `AsyncState`)."""

    env_state: Any
    timestep: Any
    carry: Any
    key: Any


class AsyncState(NamedTuple):
    """The async program's scan carry: learner + snapshot + queue + actors."""

    train: TrainState      # the learner's live train state
    snapshot: TrainState   # the actors' (possibly stale) param snapshot
    buffer: Any            # the learner-owned dataset (replay table / rollout)
    queue: QueueState      # the shared device-resident trajectory queue
    actors: ActorState     # per-actor env/carry/key, lane axis (num_actors,)
    tick: jnp.ndarray      # () int32 — completed learner ticks
    dropped: jnp.ndarray   # () int32 — chunks dropped by a full queue


def default_unroll_len(system: System) -> int:
    """The natural trajectory-chunk length for a system's dataset regime.

    Rollout-regime systems (PPO family, DIAL) unroll exactly one rollout
    per chunk, so chunk boundaries coincide with update boundaries and the
    staleness-0 run replays anakin's cadence exactly.  Replay and
    sequence-replay systems have no natural window — chunks of 8 steps
    amortise queue traffic while keeping within-chunk staleness small
    (the sequence buffer's own window striding is independent of the
    chunk length: `observe` consumes the chunk row by row).
    """
    buffer = system.init_buffer(1)
    if isinstance(buffer, RolloutState):
        return int(jax.tree_util.tree_leaves(buffer.storage)[0].shape[0])
    return 8


def _chunk_example(buffer, unroll_len: int, num_envs: int):
    """A zero trajectory chunk (time-major ``(U, num_envs, ...)`` leaves)
    matching the system's per-step `Transition` structure, recovered from
    its dataset storage.  The rollout accumulator and the sequence
    buffer's step ring both hold ``(T, num_envs, ...)`` per-step rows; the
    flat replay table holds ``(capacity, ...)`` rows."""
    if isinstance(buffer, RolloutState):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((unroll_len, num_envs) + x.shape[2:], x.dtype),
            buffer.storage,
        )
    if isinstance(buffer, SeqBufferState):
        # storage leaves are whole windows (capacity, window_len, ...);
        # the per-step transition structure lives in the step ring
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((unroll_len, num_envs) + x.shape[2:], x.dtype),
            buffer.acc,
        )
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((unroll_len, num_envs) + x.shape[1:], x.dtype),
        buffer.storage,
    )


def _actor_keys(key, num_actors: int):
    """Per-actor runner keys.  A single actor gets ``key`` itself (not a
    split of it), so the ``num_actors=1`` program consumes exactly the key
    stream anakin would — the staleness-0 bitwise pin depends on this."""
    key = jnp.asarray(key)
    if num_actors == 1:
        return key[None]
    return jax.random.split(key, num_actors)


def _shard_actors(actors: ActorState) -> ActorState:
    """Annotate actor-state leaves with the ``"actors"`` logical axis.

    Under `repro.distributed.sharding.enter_mesh` this spreads the actor
    lane axis across the mesh data axis (one replica group per device);
    outside any mesh context it is a no-op, so the unsharded smoke path
    runs the same code.  PRNG-key leaves are left unconstrained — their
    extended dtypes predate sharding-constraint support on older jax.
    """

    def _constrain(x):
        if jnp.issubdtype(jnp.asarray(x).dtype, jax.dtypes.prng_key):
            return x
        return with_logical_constraint(x, ("actors",))

    return jax.tree_util.tree_map(_constrain, actors)


def make_async(
    system: System,
    num_iterations: int,
    num_envs: int,
    num_actors: int,
    param_sync_every: int = 1,
    unroll_len: Optional[int] = None,
    queue_capacity: Optional[int] = None,
    learner_pops_per_tick: Optional[int] = None,
    log_every: int = 0,
    log_callback=None,
):
    """Build the fused async actor/learner program as a function of ``key``.

    ``num_iterations`` counts env steps per env *per actor* (anakin's
    iteration unit), so ``make_async(system, N, E, 1)`` does exactly the
    env-step work of ``make_anakin(system, N, E)``; total environment
    steps are ``num_iterations * num_envs * num_actors``.  It must divide
    into ``unroll_len``-step ticks (default: the system's rollout length,
    or 8 for replay systems — see `default_unroll_len`).

    Each tick: (1) every ``param_sync_every`` ticks the actors' snapshot
    refreshes from the learner params; (2) the vmapped actors unroll
    ``unroll_len`` acting steps each (`_act_phase` with snapshot params)
    and push their chunks into the queue; (3) the learner pops up to
    ``learner_pops_per_tick`` chunks (default ``num_actors`` — keeps up
    exactly) and runs each row through ``observe`` + the gated update,
    using the update keys shipped with the chunk.  Push to a full queue
    (default capacity ``2 * num_actors``) drops the chunk and counts it.

    The returned ``program(key)`` yields ``(AsyncState, metrics)`` with
    per-tick metrics: the actors' reward/episode-return stream plus
    ``queue_depth``, ``staleness`` (mean learner-updates-behind of the
    chunks consumed that tick), ``updates`` and cumulative ``dropped``.
    ``program.fused`` / ``program.init_fn`` expose the jits for AOT
    tooling, and ``program.unroll_len`` / ``program.num_ticks`` the
    resolved schedule.  ``log_every``/``log_callback`` install the
    `repro.obs` telemetry tap per tick, exactly as in ``make_anakin``.
    """
    if num_actors < 1:
        raise ValueError(f"num_actors must be >= 1, got {num_actors}")
    if param_sync_every < 1:
        raise ValueError(
            f"param_sync_every must be >= 1, got {param_sync_every}"
        )
    unroll = unroll_len or default_unroll_len(system)
    if num_iterations % unroll:
        raise ValueError(
            f"num_iterations ({num_iterations}) must be a multiple of the "
            f"unroll length ({unroll})"
        )
    ticks = num_iterations // unroll
    capacity = queue_capacity or 2 * num_actors
    pops = learner_pops_per_tick or num_actors

    tenv = _training_env(system.env)
    tapping = log_every > 0 and log_callback is not None
    key_data_shape = jax.random.key_data(jax.random.key(0)).shape

    def example_item(buffer):
        """A zero queue slot: chunk + per-row update keys + snapshot age."""
        return {
            "chunk": _chunk_example(buffer, unroll, num_envs),
            "k_upd": jnp.zeros((unroll,) + key_data_shape, jnp.uint32),
            "snapshot_steps": jnp.zeros((), jnp.int32),
        }

    def init_state(key) -> AsyncState:
        """Fresh AsyncState; actor lane 0 reproduces anakin's init exactly."""
        sts = jax.vmap(
            lambda k: init_system_state(system, k, num_envs, train_env=tenv)
        )(_actor_keys(key, num_actors))
        lane0 = jax.tree_util.tree_map(lambda x: x[0], sts)
        return AsyncState(
            train=lane0.train,
            snapshot=lane0.train,
            buffer=lane0.buffer,
            queue=queue_init(example_item(lane0.buffer), capacity),
            actors=ActorState(
                sts.env_state, sts.timestep, sts.carry, sts.key
            ),
            tick=jnp.zeros((), jnp.int32),
            dropped=jnp.zeros((), jnp.int32),
        )

    def one_actor(snapshot, act: ActorState):
        """Unroll one actor replica for ``unroll`` steps under the snapshot."""

        def _step(carry, _):
            env_state, ts, rnn_carry, key = carry
            env_state, ts, rnn_carry, key, tr, k_upd, m = _act_phase(
                system, tenv, snapshot, env_state, ts, rnn_carry, key
            )
            return (env_state, ts, rnn_carry, key), (
                tr, jax.random.key_data(k_upd), m
            )

        (env_state, ts, rnn_carry, key), (chunk, k_upds, ms) = jax.lax.scan(
            _step,
            (act.env_state, act.timestep, act.carry, act.key),
            None,
            length=unroll,
        )
        return ActorState(env_state, ts, rnn_carry, key), chunk, k_upds, ms

    def consume_chunk(train, buffer, item):
        """Feed one chunk row-by-row through observe + the gated update —
        the exact per-iteration cadence anakin's `_one_iteration` has, so
        the data-to-update ratio is regime-faithful at any actor count."""

        def _row(carry, x):
            train, buffer = carry
            tr, k_data = x
            buffer = system.observe(buffer, tr)
            train, buffer = jax.lax.cond(
                system.can_sample(buffer),
                lambda tb: _do_updates(
                    system, tb[0], tb[1], jax.random.wrap_key_data(k_data)
                ),
                lambda tb: tb,
                (train, buffer),
            )
            return (train, buffer), ()

        (train, buffer), _ = jax.lax.scan(
            _row, (train, buffer), (item["chunk"], item["k_upd"])
        )
        return train, buffer

    def learner_phase(train, buffer, queue):
        """Pop up to ``pops`` chunks and consume each (empty-queue gated)."""

        def _pop_one(carry, _):
            train, buffer, queue, stale_sum, consumed = carry

            def _do_pop(operand):
                train, buffer, queue, stale_sum, consumed = operand
                queue, item = queue_pop(queue)
                staleness = (
                    train.steps - item["snapshot_steps"]
                ).astype(jnp.float32)
                train, buffer = consume_chunk(train, buffer, item)
                return train, buffer, queue, stale_sum + staleness, consumed + 1

            return (
                jax.lax.cond(
                    queue.size > 0,
                    _do_pop,
                    lambda op: op,
                    (train, buffer, queue, stale_sum, consumed),
                ),
                (),
            )

        (train, buffer, queue, stale_sum, consumed), _ = jax.lax.scan(
            _pop_one,
            (
                train, buffer, queue,
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
            ),
            None,
            length=pops,
        )
        staleness = stale_sum / jnp.maximum(consumed, 1).astype(jnp.float32)
        return train, buffer, queue, staleness, consumed

    def tick_fn(state: AsyncState):
        """One learner tick: sync -> actor unrolls -> pushes -> learner pops."""
        snapshot = jax.lax.cond(
            state.tick % param_sync_every == 0,
            lambda _: state.train,
            lambda s: s,
            state.snapshot,
        )
        actors, chunks, k_upds, ms = jax.vmap(
            lambda a: one_actor(snapshot, a)
        )(state.actors)
        actors = _shard_actors(actors)

        queue, dropped = state.queue, state.dropped
        for a in range(num_actors):
            item = {
                "chunk": jax.tree_util.tree_map(lambda x: x[a], chunks),
                "k_upd": k_upds[a],
                "snapshot_steps": snapshot.steps,
            }
            queue, ok = queue_push(queue, item)
            dropped = dropped + (1 - ok.astype(jnp.int32))
        depth = queue.size

        train, buffer, queue, staleness, consumed = learner_phase(
            state.train, state.buffer, queue
        )
        metrics = {
            **jax.tree_util.tree_map(jnp.mean, ms),  # (A, U) -> scalar
            "queue_depth": depth.astype(jnp.float32),
            "staleness": staleness,
            "consumed": consumed.astype(jnp.float32),
            "dropped": dropped.astype(jnp.float32),
        }
        state = AsyncState(
            train=train,
            snapshot=snapshot,
            buffer=buffer,
            queue=queue,
            actors=actors,
            tick=state.tick + 1,
            dropped=dropped,
        )
        return state, metrics

    if tapping:
        tapped = _tap_body(tick_fn, log_every, log_callback)

        def _body(carry, it):
            return tapped(carry, it)
    else:
        def _body(carry, _):
            return tick_fn(carry)

    def run(state):
        """The fused scan over ticks."""
        xs = jnp.arange(ticks) if tapping else None
        return jax.lax.scan(_body, state, xs, length=ticks)

    init_fn = jax.jit(lambda key: _unalias(init_state(key)))
    fused = jax.jit(run, donate_argnums=0)

    def program(key):
        """Run the async program from ``key``; returns (state, metrics)."""
        return fused(init_fn(key))

    program.fused = fused
    program.init_fn = init_fn
    program.unroll_len = unroll
    program.num_ticks = ticks
    return program


def train_async(
    system: System,
    key,
    num_iterations: int,
    num_envs: int,
    num_actors: int,
    param_sync_every: int = 1,
    unroll_len: Optional[int] = None,
    queue_capacity: Optional[int] = None,
    learner_pops_per_tick: Optional[int] = None,
    log_every: int = 0,
    log_callback=None,
):
    """One-shot `make_async` run: IMPALA-style actor/learner training.

    Returns ``(AsyncState, metrics)`` — ``state.train`` is the learner's
    final train state, metrics the per-tick stream (see `make_async`).
    When the telemetry tap is installed this wrapper drains the async
    callback queue before returning, exactly like ``train_anakin``.
    """
    out = make_async(
        system,
        num_iterations,
        num_envs,
        num_actors,
        param_sync_every=param_sync_every,
        unroll_len=unroll_len,
        queue_capacity=queue_capacity,
        learner_pops_per_tick=learner_pops_per_tick,
        log_every=log_every,
        log_callback=log_callback,
    )(key)
    if log_every > 0 and log_callback is not None:
        jax.block_until_ready(out)
        jax.effects_barrier()
    return out
