"""Logical-axis sharding: map logical axis names to mesh axes.

Model code annotates every parameter and key activation with *logical* axis
names ("vocab", "heads", "ffn", "expert", "batch", ...). A rule table maps
logical names to physical mesh axes; `tree_shardings` converts a pytree of
logical-axis tuples into a pytree of NamedShardings for pjit in/out specs.

Changing a sharding strategy (e.g. for a §Perf experiment) means swapping the
rule table, not touching model code.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Baseline rule table: tensor parallelism over "model", batch data-parallel
# over ("pod","data") when a pod axis exists.
DEFAULT_RULES: Dict[str, object] = {
    "batch": ("pod", "data"),  # activations' batch dim
    "actors": ("pod", "data"),  # async runner's actor-replica lane axis
    "seq": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",  # flash-decoding cache sharding (opt-in via cache axes)
    "head_dim": None,
    "embed": None,
    "ffn": "model",
    "expert": "model",
    "expert_ffn": None,
    "dinner": "model",
    "state": None,
    "layers": None,
    "codebooks": None,
}

# FSDP+TP: additionally shard the d_model ("embed") dim of weights over the
# data axis — required for 405B/1T-class params to fit per-device HBM. For
# activations the "embed" rule is inert because the batch dim claims the
# data axis first (logical_to_spec never reuses a mesh axis within a spec).
FSDP_TP_RULES: Dict[str, object] = dict(DEFAULT_RULES, embed="data")

# + sequence parallelism: residual activations between layers are sharded on
# the sequence dim over "model" (attention/FFN internals gather as needed) —
# divides stored per-layer residuals by the model-axis size.
FSDP_TP_SP_RULES: Dict[str, object] = dict(FSDP_TP_RULES, seq="model")

PROFILES: Dict[str, Dict[str, object]] = {
    "tp": DEFAULT_RULES,
    "fsdp_tp": FSDP_TP_RULES,
    "fsdp_tp_sp": FSDP_TP_SP_RULES,
}


def rules_for(profile: str) -> Dict[str, object]:
    """The rule table registered under ``profile`` (see `PROFILES`)."""
    return PROFILES[profile]


# Ambient rule table used by with_logical_constraint inside model code.
# Set per-lowering (e.g. the dry-run wraps lowering in set_active_rules) so
# activation-sharding experiments don't require touching model code.
_ACTIVE_RULES: list = [DEFAULT_RULES]


class set_active_rules:
    """Context manager installing a rule table (by dict or profile name)
    as the ambient rules `with_logical_constraint` reads by default."""

    def __init__(self, rules):
        self.rules = rules if isinstance(rules, dict) else rules_for(rules)

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


def active_rules() -> Dict[str, object]:
    """The innermost rule table installed by `set_active_rules`."""
    return _ACTIVE_RULES[-1]


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def logical_to_spec(
    logical_axes: Optional[Sequence[Optional[str]]],
    rules: Dict[str, object],
    mesh: Mesh,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Convert a tuple of logical axis names to a PartitionSpec valid on mesh.

    If `shape` is given, mesh axes whose size does not divide the
    corresponding dimension are dropped (JAX rejects uneven shardings at jit
    boundaries) — e.g. 8 kv heads on a 16-way "model" axis fall back to
    replicated.
    """
    if logical_axes is None:
        return P()
    mesh_shape = dict(zip(mesh.axis_names, mesh.shape.values() if hasattr(mesh.shape, "values") else mesh.shape))
    mesh_axes = set(mesh.axis_names)
    used = set()
    entries = []
    for i, name in enumerate(logical_axes):
        if name is None:
            entries.append(None)
            continue
        target = rules.get(name, None)
        if target is None:
            entries.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        # keep only axes present in this mesh and not already used in this spec
        phys = tuple(a for a in target if a in mesh_axes and a not in used)
        if shape is not None and phys:
            dim = shape[i]
            kept = []
            prod = 1
            for a in phys:
                asize = mesh_shape[a]
                if dim % (prod * asize) == 0:
                    kept.append(a)
                    prod *= asize
            phys = tuple(kept)
        used.update(phys)
        if not phys:
            entries.append(None)
        elif len(phys) == 1:
            entries.append(phys[0])
        else:
            entries.append(phys)
    # trim trailing Nones for cleanliness
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(
    axes_tree,
    mesh: Mesh,
    rules: Optional[Dict[str, object]] = None,
    shapes_tree=None,
):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings.

    Leaves of `axes_tree` are tuples (possibly empty) of logical names or
    None entries. `None` leaves map to fully-replicated shardings. If
    `shapes_tree` (a matching pytree of arrays / ShapeDtypeStructs) is given,
    non-divisible mesh axes are dropped per-leaf.
    """
    rules = DEFAULT_RULES if rules is None else rules
    is_leaf = lambda x: x is None or isinstance(x, tuple)

    if shapes_tree is None:
        return jax.tree_util.tree_map(
            lambda axes: NamedSharding(mesh, logical_to_spec(axes, rules, mesh)),
            axes_tree,
            is_leaf=is_leaf,
        )
    return jax.tree_util.tree_map(
        lambda axes, arr: NamedSharding(
            mesh, logical_to_spec(axes, rules, mesh, shape=arr.shape)
        ),
        axes_tree,
        shapes_tree,
        is_leaf=is_leaf,
    )


# One probe for both helpers: the mesh must be installed and read through
# the same mechanism, or with_logical_constraint silently sees no mesh (e.g.
# a jax with get_abstract_mesh but no set_mesh would install via the legacy
# context but read the empty abstract mesh).
_HAS_AMBIENT_MESH_API = hasattr(jax, "set_mesh") and hasattr(
    jax.sharding, "get_abstract_mesh"
)


def enter_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    `jax.set_mesh` on newer jax; on older releases Mesh itself is the
    (legacy thread-resources) context manager.
    """
    if _HAS_AMBIENT_MESH_API:
        return jax.set_mesh(mesh)
    return mesh


def _ambient_mesh():
    """The mesh installed by `enter_mesh`, or None outside any context."""
    if _HAS_AMBIENT_MESH_API:
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources  # legacy ambient mesh

    return thread_resources.env.physical_mesh


def with_logical_constraint(x, logical_axes, rules=None):
    """Apply a sharding constraint from logical axes inside jit.

    Uses the ambient mesh (set via enter_mesh); outside any mesh context
    this is a no-op so the same model code runs in unsharded smoke tests.
    Non-divisible axes are dropped (see logical_to_spec).
    """
    env_mesh = _ambient_mesh()
    if env_mesh is None or env_mesh.empty:
        return x
    rules = active_rules() if rules is None else rules
    spec = logical_to_spec(logical_axes, rules, env_mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, spec)
