from repro.distributed.sharding import (
    DEFAULT_RULES,
    logical_to_spec,
    tree_shardings,
    with_logical_constraint,
)

__all__ = [
    "DEFAULT_RULES",
    "logical_to_spec",
    "tree_shardings",
    "with_logical_constraint",
]
