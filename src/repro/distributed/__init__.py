"""Distribution subsystem: logical-axis sharding + the async runner.

Two halves (see docs/DISTRIBUTED.md):

* `repro.distributed.sharding` — the logical-axis rule tables and mesh
  helpers that map model/runner annotations ("batch", "actors", ...) to
  physical mesh axes;
* `repro.distributed.impala` — the IMPALA-style async actor/learner
  runner (`make_async` / `train_async`), the fourth runner scale after
  python-loop / anakin / shard_map.
"""
from repro.distributed.impala import (
    ActorState,
    AsyncState,
    default_unroll_len,
    make_async,
    train_async,
)
from repro.distributed.sharding import (
    DEFAULT_RULES,
    enter_mesh,
    logical_to_spec,
    tree_shardings,
    with_logical_constraint,
)

__all__ = [
    "ActorState",
    "AsyncState",
    "DEFAULT_RULES",
    "default_unroll_len",
    "enter_mesh",
    "logical_to_spec",
    "make_async",
    "train_async",
    "tree_shardings",
    "with_logical_constraint",
]
