"""Env-backed synthetic traffic for the decision-serving engine.

``BENCH_serve`` needs a reproducible stand-in for "millions of users":
``N`` concurrent user streams, each submitting episode requests whose
inter-arrival gaps are exponential — a per-stream Poisson process, merged
into one arrival sequence measured in engine ticks.  Everything is seeded
numpy, so a (seed, streams, rate) triple always replays the same traffic.

`serve_workload` drives a `DecisionEngine` through one such trace and
reduces its ``tick_log`` into the artifact's latency/throughput block:
every decision made in a tick experiences that tick's wall time, so the
per-decision latency distribution is the tick times weighted by live-slot
counts — p50/p99 over exactly the decisions that were served.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.serve.engine import DecisionEngine, ServeRequest


def poisson_requests(
    num_streams: int,
    episodes_per_stream: int,
    arrival_rate: float,
    seed: int = 0,
) -> List[ServeRequest]:
    """Poisson arrivals over ``num_streams`` concurrent streams.

    Each stream emits ``episodes_per_stream`` episode requests with
    exponential inter-arrival gaps of rate ``arrival_rate`` (requests per
    tick per stream); streams are merged and sorted by arrival tick (ties
    broken by stream id, keeping admission order deterministic).  Each
    request carries its own episode reset key, derived from ``seed`` and
    its (stream, index) coordinates.  Arrival ticks ride in
    ``ServeRequest.arrival_tick``; uids number the merged sequence 0..R-1.
    """
    if arrival_rate <= 0:
        raise ValueError(f"arrival_rate must be > 0, got {arrival_rate}")
    rng = np.random.default_rng(seed)
    arrivals = []  # (tick, stream, index)
    for s in range(num_streams):
        gaps = rng.exponential(1.0 / arrival_rate, size=episodes_per_stream)
        ticks = np.floor(np.cumsum(gaps)).astype(np.int64)
        for j, t in enumerate(ticks):
            arrivals.append((int(t), s, j))
    arrivals.sort()
    base = jax.random.key(seed)
    requests = []
    for uid, (tick, s, j) in enumerate(arrivals):
        key = jax.random.fold_in(jax.random.fold_in(base, s), j)
        requests.append(ServeRequest(uid=uid, key=key, arrival_tick=tick))
    return requests


def serve_workload(
    engine: DecisionEngine,
    requests: Sequence[ServeRequest],
    max_ticks: int = 1_000_000,
) -> Dict:
    """Replay an arrival trace through ``engine`` and reduce the stats.

    Requests are submitted when the engine's tick counter passes their
    ``arrival_tick``; idle gaps between arrivals are skipped rather than
    ticked through.  Returns the
    BENCH_serve measurement block: per-decision latency percentiles,
    decisions/sec, tick/decision/episode counts and the served episodes'
    mean team return.
    """
    pending = sorted(requests, key=lambda r: (r.arrival_tick, r.uid))
    first_logged = len(engine.tick_log)
    clock = 0
    i = 0
    for _ in range(max_ticks):
        while i < len(pending) and pending[i].arrival_tick <= clock:
            engine.submit(pending[i])
            i += 1
        if engine.idle():
            if i >= len(pending):
                break
            clock = pending[i].arrival_tick  # skip the idle gap
            continue
        engine.tick()
        clock += 1
    log = engine.tick_log[first_logged:]
    return workload_stats(log, engine.finished)


def workload_stats(tick_log: Sequence[Dict], finished: Sequence[ServeRequest]) -> Dict:
    """Reduce a tick log + finished episodes to the BENCH_serve cell block."""
    if not tick_log:
        raise ValueError("empty tick log: the workload never served a decision")
    seconds = np.asarray([t["seconds"] for t in tick_log], np.float64)
    live = np.asarray([t["live"] for t in tick_log], np.int64)
    # each of a tick's `live` decisions experienced that tick's wall time
    per_decision = np.repeat(seconds, live)
    total = float(seconds.sum())
    decisions = int(live.sum())
    returns = [r.episode_return for r in finished]
    return {
        "ticks": len(tick_log),
        "decisions": decisions,
        "episodes": len(finished),
        "decisions_per_sec": decisions / total if total > 0 else 0.0,
        "latency": {
            "p50_ms": float(np.percentile(per_decision, 50) * 1e3),
            "p99_ms": float(np.percentile(per_decision, 99) * 1e3),
            "mean_ms": float(per_decision.mean() * 1e3),
        },
        "mean_live_slots": float(live.mean()),
        "episode_return_mean": float(np.mean(returns)) if returns else 0.0,
        "wall_seconds": total,
    }
