"""Self-describing MARL policy checkpoints (the train -> serve hand-off).

A *policy checkpoint* is a directory holding the trained `TrainState`
pytree (saved through `repro.checkpoint.ckpt`, the same .npz path the LM
side uses) next to a ``policy.json`` metadata document recording the
registry system name, env name, config overrides and provenance — so a
checkpoint can be restored by name alone, with no reference to the
training script that produced it:

    save_policy(dir, "rec_ippo", "matrix_game", train_state)
    env, system, train = load_policy(dir)        # rebuilt from the registry

Seed-vectorized training (``train_anakin(..., num_seeds=N)``) produces
train states whose every leaf carries a leading ``(N,)`` lane axis;
`save_policy` splits those into per-seed lanes (``seed_0/ .. seed_{N-1}/``)
so each lane restores as an ordinary single-seed policy
(``load_policy(dir, seed=k)``).

The shard_map runner returns bare replicated params rather than a full
`TrainState`; those save with ``"tree": "params"`` and restore wrapped in
a zero-step `TrainState` (enough to serve and evaluate, not to resume
optimisation — recorded honestly in the metadata).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.system import init_system_state
from repro.core.types import SystemState, TrainState

POLICY_META = "policy.json"
_FORMAT = "marl-policy-v1"


def _is_train_state(tree) -> bool:
    """True when ``tree`` is a full `TrainState` (vs bare params)."""
    return isinstance(tree, TrainState)


def save_policy(
    directory: str,
    system_name: str,
    env_name: str,
    train: Any,
    *,
    config_overrides: Optional[dict] = None,
    env_kwargs: Optional[dict] = None,
    num_seeds: Optional[int] = None,
    step: int = 0,
) -> str:
    """Write a self-describing policy checkpoint directory.

    ``train`` is a full `TrainState` (params + optimizer state + steps) or
    bare params (the shard_map runner's replicated output).  With
    ``num_seeds`` set, every leaf of ``train`` must carry a leading
    ``(num_seeds,)`` lane axis (seed-vectorized training output); each
    lane is saved under ``seed_<s>/`` as an independent policy.  Returns
    the metadata path.
    """
    from repro.obs import provenance  # deferred: pulls in jax device init

    os.makedirs(directory, exist_ok=True)
    if num_seeds:
        for s in range(num_seeds):
            lane = jax.tree_util.tree_map(lambda x: jnp.asarray(x)[s], train)
            save_checkpoint(os.path.join(directory, f"seed_{s}"), step, lane)
    else:
        save_checkpoint(directory, step, train)
    meta = {
        "format": _FORMAT,
        "system": system_name,
        "env": env_name,
        "config_overrides": _jsonable(config_overrides or {}),
        "env_kwargs": _jsonable(env_kwargs or {}),
        "num_seeds": num_seeds,
        "step": step,
        "tree": "train_state" if _is_train_state(train) else "params",
        "provenance": provenance(),
    }
    path = os.path.join(directory, POLICY_META)
    with open(path, "w") as f:
        json.dump(meta, f, indent=2)
    return path


def read_policy_meta(directory: str) -> dict:
    """The ``policy.json`` metadata document of a checkpoint directory."""
    with open(os.path.join(directory, POLICY_META)) as f:
        meta = json.load(f)
    if meta.get("format") != _FORMAT:
        raise ValueError(
            f"{directory!r} is not a {_FORMAT} checkpoint "
            f"(format={meta.get('format')!r})"
        )
    return meta


def load_policy(
    directory: str, seed: Optional[int] = None
) -> Tuple[Any, Any, TrainState]:
    """Restore ``(env, system, train_state)`` from a policy checkpoint.

    The (env, system) pair is rebuilt from the registries using the
    recorded names and config overrides, so the restored `TrainState`
    lands in exactly the pytree structure the system's ``init_train``
    produces — the round trip the serving engine and the evaluator both
    consume.  For a per-seed checkpoint, ``seed`` picks the lane
    (default 0).
    """
    from repro.systems.registry import make_pair  # deferred: heavy import

    meta = read_policy_meta(directory)
    ckpt_dir = directory
    if meta.get("num_seeds"):
        seed = 0 if seed is None else seed
        if not 0 <= seed < meta["num_seeds"]:
            raise ValueError(
                f"seed {seed} out of range for a {meta['num_seeds']}-seed "
                "checkpoint"
            )
        ckpt_dir = os.path.join(directory, f"seed_{seed}")
    elif seed not in (None, 0):
        raise ValueError(f"{directory!r} is a single-seed checkpoint")

    overrides = _tupled(meta.get("config_overrides", {}))
    env_kwargs = meta.get("env_kwargs") or None
    env, system = make_pair(
        meta["system"], meta["env"], env_kwargs=env_kwargs, **overrides
    )
    target = system.init_train(jax.random.key(0))
    if meta.get("tree") == "params":
        params = restore_checkpoint(ckpt_dir, meta["step"], target.params)
        train = TrainState(
            params=params,
            target_params=params,
            opt_state=target.opt_state,
            steps=jnp.zeros((), jnp.int32),
        )
    else:
        train = restore_checkpoint(ckpt_dir, meta["step"], target)
    # restore_checkpoint returns numpy leaves; put them on device once so
    # the serving tick doesn't re-transfer the params every call
    return env, system, jax.device_put(train)


def fresh_system_state(system, train: TrainState, key, num_envs: int) -> SystemState:
    """A fresh `SystemState` carrying a restored trainer.

    The round trip the checkpoint satellite pins: envs, buffer and carry
    are initialised from scratch (new episodes, empty dataset, zero
    memory) while the trainer resumes from the checkpoint — ready for any
    runner or for further training.
    """
    st = init_system_state(system, key, num_envs)
    return st._replace(train=jax.tree_util.tree_map(jnp.asarray, train))


def _jsonable(obj):
    """Tuples -> lists, scalars passed through (json round-trip safety)."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (tuple, list)):
        return [_jsonable(v) for v in obj]
    return obj


def _tupled(obj):
    """Lists -> tuples on the way back in (configs declare tuple fields)."""
    if isinstance(obj, dict):
        return {k: _tupled(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return tuple(_tupled(v) for v in obj)
    return obj
