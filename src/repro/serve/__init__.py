"""repro.serve — checkpointed MARL policies behind a slot-based engine.

The "millions of users" half of the north star: take any trained REGISTRY
system (feed-forward or recurrent), persist it as a self-describing
checkpoint, and serve per-user episodes as live decision traffic —

* `save_policy` / `load_policy` / `fresh_system_state`
  (`repro.serve.checkpoint`) — the train -> serve hand-off;
* `DecisionEngine` / `ServeRequest` (`repro.serve.engine`) — the fixed
  slot pool advancing all live episodes with one jitted tick;
* `poisson_requests` / `serve_workload` (`repro.serve.traffic`) — the
  reproducible synthetic-traffic harness behind ``BENCH_serve``.

Driver: ``python -m repro.launch.serve_marl`` (see docs/SERVING.md).
"""
from repro.serve.checkpoint import (
    fresh_system_state,
    load_policy,
    read_policy_meta,
    save_policy,
)
from repro.serve.engine import DecisionEngine, ServeRequest
from repro.serve.traffic import poisson_requests, serve_workload, workload_stats

__all__ = [
    "DecisionEngine",
    "ServeRequest",
    "fresh_system_state",
    "load_policy",
    "poisson_requests",
    "read_policy_meta",
    "save_policy",
    "serve_workload",
    "workload_stats",
]
