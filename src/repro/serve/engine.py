"""Slot-based decision-serving engine for trained MARL policies.

The MARL twin of the LM side's continuous-batching engine
(`repro.serving.engine`): a fixed pool of ``max_slots`` *episode slots*
shares one batched env/carry state, per-user episode requests are admitted
into free slots, and one jitted tick advances **all** live slots — policy
forward pass, env step and carry bookkeeping fused into a single program
whose shapes never change, so the jit compiles once per pool size.

Per-slot recurrent state is exactly the typed `repro.core.types.Carry` the
memory-core protocol provides: one row per slot, zeroed on admission and
at episode boundaries through the protocol's one masking rule
(`repro.nn.recurrent.reset_carry`).  A feed-forward policy's carry is the
empty pytree and all of this is free.

Action modes map onto the executor's existing faces:

* ``greedy``  — ``select_actions(..., training=False)``: the same
  deterministic argmax path as `repro.eval`'s fused evaluator, which is
  what makes served decisions bitwise-comparable to offline eval;
* ``sample``  — ``training=True``: the stochastic behaviour policy
  (eps-greedy / categorical sampling), for serving exploratory traffic.

Simplifications vs a production server (documented, not hidden — same
discipline as the LM engine):

* free slots still burn forward-pass and env-step FLOPs (their outputs
  are discarded); fine at these pool sizes, masking would fix it at scale;
* admission resets one env per request (a tiny jitted call per admit)
  rather than batching arrivals into one reset.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import TrainState
from repro.envs.api import StepType
from repro.nn.recurrent import reset_carry


@dataclasses.dataclass
class ServeRequest:
    """One user's episode: a reset key in, decisions and a return out."""

    uid: int
    key: Any  # jax PRNG key seeding the episode's env.reset
    arrival_tick: int = 0  # when the traffic trace makes this request arrive
    # filled by the engine
    slot: Optional[int] = None
    episode_return: float = 0.0             # team return (mean over agents)
    agent_returns: Dict[str, float] = dataclasses.field(default_factory=dict)
    length: int = 0
    done: bool = False
    actions: List[Dict[str, Any]] = dataclasses.field(default_factory=list)


def _strong(tree):
    """Strip weak types so pool state keeps one aval across jit boundaries.

    ``env.step`` and ``env.reset`` disagree on weak-typedness for some
    leaves (e.g. rewards); without canonicalising, the admit and tick jits
    would each recompile once when state produced by one flows into the
    other — a latency spike BENCH_serve would wrongly report as a slow
    steady-state tick.
    """
    return jax.tree_util.tree_map(lambda x: jnp.asarray(x, x.dtype), tree)


def _as_train_state(train_or_params) -> TrainState:
    """Accept a full TrainState or bare params (wrapped with zero steps)."""
    if isinstance(train_or_params, TrainState):
        return train_or_params
    return TrainState(
        params=train_or_params,
        target_params=train_or_params,
        opt_state=None,
        steps=jnp.zeros((), jnp.int32),
    )


class DecisionEngine:
    """Serve per-user episodes of ``system``'s env from a fixed slot pool.

        env, system, train = load_policy("results/ckpts/rec_ippo-lbf")
        engine = DecisionEngine(system, train, max_slots=8)
        engine.submit(ServeRequest(uid=0, key=jax.random.key(7)))
        while not engine.idle():
            decisions = engine.tick()   # {uid: {agent: action}} this tick

    ``tick()`` admits queued requests into free slots (lowest slot index
    first, FIFO queue — deterministic recycling), runs the one jitted
    select-actions + env-step program over the whole pool, returns the
    live slots' joint actions, and retires episodes that hit LAST (the
    slot is freed for the next admission, its carry already zeroed by the
    in-tick boundary reset).  Per-tick wall time and live-slot counts are
    appended to ``tick_log`` for the BENCH_serve latency/throughput stats.
    """

    def __init__(
        self,
        system,
        train,
        max_slots: int = 8,
        mode: str = "greedy",
        seed: int = 0,
        record_actions: bool = False,
        warmup: bool = True,
    ):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if mode not in ("greedy", "sample"):
            raise ValueError(f"mode must be 'greedy' or 'sample', got {mode!r}")
        self.system = system
        self.env = system.env  # raw env: LAST retires the slot, no auto-reset
        self.train = jax.device_put(_as_train_state(train))
        self.max_slots = max_slots
        self.mode = mode
        self.record_actions = record_actions
        self._ids = list(system.spec.agent_ids)
        k_pool, k_warm, k_act = jax.random.split(jax.random.key(seed), 3)
        self._warm_key = k_warm
        self._act_base = k_act
        self._t = 0  # tick counter (drives the sample-mode key stream)

        self.queue: Deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots
        self.finished: List[ServeRequest] = []
        self.tick_log: List[Dict[str, float]] = []  # wall seconds + live count

        # the pool: batched env state / timestep / carry, one row per slot
        # (free rows hold placeholder episodes that are stepped and ignored)
        env_state, ts = jax.vmap(self.env.reset)(
            jax.random.split(k_pool, max_slots)
        )
        self._env_state, self._ts = _strong((env_state, ts))
        self._carry = system.initial_carry((max_slots,))
        self._live = np.zeros(max_slots, dtype=bool)

        self._admit_jit = jax.jit(self._admit_fn)
        self._tick_jit = jax.jit(self._tick_fn)
        if warmup:
            self.warmup()

    # ---------------------------------------------------------- jitted core

    def _admit_fn(self, env_state, ts, carry, key, slot):
        """Reset one episode into pool row ``slot`` and zero its carry.

        ``slot`` is a traced scalar, so one compiled program serves every
        admission.  The carry reset routes through `reset_carry` — the
        memory-core protocol's single masking rule — with a one-hot slot
        mask, exactly as the training runners reset at FIRST boundaries.
        """
        one_state, one_ts = self.env.reset(key)
        merge = lambda pool, one: pool.at[slot].set(one)
        env_state = jax.tree_util.tree_map(merge, env_state, one_state)
        ts = jax.tree_util.tree_map(merge, ts, one_ts)
        mask = jnp.arange(self.max_slots) == slot
        carry = reset_carry(
            carry, mask, initial=self.system.initial_carry((self.max_slots,))
        )
        return _strong((env_state, ts, carry))

    def _tick_fn(self, train, env_state, ts, carry, key):
        """One fused decision tick over the whole pool.

        Policy forward pass (greedy or sampled), vectorised env step, and
        the episode-boundary carry reset (rows whose step hit LAST restart
        from zero memory, so a recycled slot can never leak the previous
        user's state) — all inside one jit.
        """
        gs = jax.vmap(self.env.global_state)(env_state)
        actions, carry, _ = self.system.select_actions(
            train, ts.observation, gs, carry, key,
            training=(self.mode == "sample"),
        )
        new_env_state, new_ts = jax.vmap(self.env.step)(env_state, actions)
        ended = new_ts.step_type == StepType.LAST
        carry = reset_carry(
            carry, ended,
            initial=self.system.initial_carry((self.max_slots,)),
        )
        return _strong(
            (actions, new_env_state, new_ts, carry, new_ts.reward, ended)
        )

    def warmup(self) -> None:
        """Compile the admit/tick programs off the latency-critical path.

        Both are pure functions, so running them on the current pool state
        and discarding the outputs changes nothing; BENCH_serve latencies
        then measure steady-state decisions, not first-call compilation.
        """
        jax.block_until_ready(
            self._admit_jit(
                self._env_state, self._ts, self._carry,
                self._warm_key, jnp.asarray(0),
            )
        )
        jax.block_until_ready(
            self._tick_jit(
                self.train, self._env_state, self._ts, self._carry,
                jax.random.fold_in(self._warm_key, 1),
            )
        )

    # ------------------------------------------------------------ admission

    def submit(self, req: ServeRequest) -> None:
        """Queue one episode request (FIFO; admitted on the next tick)."""
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill free slots from the queue: lowest slot first, FIFO order."""
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.slot = slot
            self.slots[slot] = req
            self._live[slot] = True
            self._env_state, self._ts, self._carry = self._admit_jit(
                self._env_state, self._ts, self._carry,
                req.key, jnp.asarray(slot),
            )
            req.agent_returns = {a: np.float32(0.0) for a in self._ids}

    # ----------------------------------------------------------------- tick

    def idle(self) -> bool:
        """True when no request is queued or being served."""
        return not self.queue and not self._live.any()

    def tick(self) -> Dict[int, Dict[str, int]]:
        """Admit, decide one joint action for every live slot, retire LASTs.

        Returns ``{uid: {agent_id: action}}`` for the slots that were live
        this tick — the decisions a server would ship back to its users.
        """
        t0 = time.perf_counter()
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return {}
        k_act = jax.random.fold_in(self._act_base, self._t)
        self._t += 1
        actions, self._env_state, self._ts, self._carry, rewards, ended = (
            self._tick_jit(
                self.train, self._env_state, self._ts, self._carry, k_act
            )
        )
        actions = {a: np.asarray(v) for a, v in actions.items()}
        rewards = {a: np.asarray(v, np.float32) for a, v in rewards.items()}
        ended = np.asarray(ended)

        emitted: Dict[int, Dict[str, int]] = {}
        for i in live:
            req = self.slots[i]
            decision = {a: actions[a][i] for a in self._ids}
            emitted[req.uid] = decision
            if self.record_actions:
                req.actions.append(decision)
            for a in self._ids:
                # float32 accumulation, same order as the evaluator's scan
                req.agent_returns[a] = np.float32(
                    req.agent_returns[a] + rewards[a][i]
                )
            req.length += 1
            if ended[i]:
                req.episode_return = float(
                    np.mean(
                        np.stack(
                            [req.agent_returns[a] for a in self._ids]
                        ).astype(np.float32)
                    )
                )
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
                self._live[i] = False
        self.tick_log.append(
            {"seconds": time.perf_counter() - t0, "live": len(live)}
        )
        return emitted

    def run_until_drained(self, max_ticks: int = 100_000) -> List[ServeRequest]:
        """Tick until the queue and every slot are empty; return finished."""
        for _ in range(max_ticks):
            if self.idle():
                break
            self.tick()
        return self.finished

    # -------------------------------------------------------- introspection

    @property
    def carry(self):
        """The pool's executor memory (one row per slot) — for tests."""
        return self._carry

    @property
    def num_live(self) -> int:
        """How many slots currently hold a running episode."""
        return int(self._live.sum())
