from repro.core.system import (
    System,
    run_environment_loop,
    train_anakin,
    train_distributed,
    init_system_state,
)
from repro.core.types import EvalMetrics, Transition, TrainState, SystemState
from repro.core import architectures, buffer, modules

__all__ = [
    "System",
    "run_environment_loop",
    "train_anakin",
    "train_distributed",
    "init_system_state",
    "EvalMetrics",
    "Transition",
    "TrainState",
    "SystemState",
    "architectures",
    "buffer",
    "modules",
]
