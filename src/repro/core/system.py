"""The Mava *system* abstraction and its runners.

A System bundles the executor (select_actions + carry), the trainer (update)
and the dataset (buffer) exactly as in the paper's Fig. 2, but as a pytree of
pure functions, so one system definition runs at every scale:

  run_environment_loop — the paper's Block-1 python loop (one env, one
      process): the *faithful* Acme-style baseline used in benchmarks as the
      pre-JAX reference point.
  train_anakin — the whole loop (env steps, replay, updates) fused into a
      single lax.scan under jit, vmapped over num_envs parallel environments.
      This is the JAX rewrite's core move and the source of the 10-100x
      speedup claim.
  train_distributed — shard_map over the mesh "data" axis: each device runs
      its own envs + replay shard (the paper's num_executors), updates are
      synchronised by gradient pmean inside the update (the Launchpad
      CourierNode graph collapsed into one SPMD program).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.types import EvalMetrics, SystemState, TrainState, Transition
from repro.envs.api import StepType
from repro.envs.wrappers import AutoReset, EpisodeStats, replace_reset_keys
from repro.nn.recurrent import reset_carry


@dataclasses.dataclass(frozen=True)
class System:
    """A full MARL algorithm specification (executor + trainer + dataset).

    The dataset half is an *experience-collection protocol* with three
    regimes:

      * replay (MADQN/VDN/QMIX/MADDPG): ``observe`` writes per-step rows
        into a circular table, ``can_sample`` gates on fill, ``update``
        samples i.i.d. minibatches and returns the buffer unchanged;
      * rollout (IPPO/MAPPO/DIAL): ``observe`` appends to a time-major
        ``rollout_len`` accumulator, ``can_sample`` fires exactly when the
        rollout is complete, and ``update`` consumes the whole trajectory
        and returns the buffer *reset* (consume-and-reset);
      * sequence replay (rec-MADQN): ``observe`` streams steps through a
        rolling ring that flushes fixed-length overlapping windows into a
        FIFO window table (`repro.core.buffer.SeqBufferState`),
        ``can_sample`` gates on the stored-window count (a pure function
        of the step counter), and ``update`` samples whole windows for
        burn-in + BPTT and returns the buffer unchanged.

    Executors may thread act-time side outputs (log-probs, values, outgoing
    messages, incoming recurrent carries) to the trainer by returning them
    as the third element of ``select_actions``; the runners store them in
    ``Transition.extras``.
    """

    env: Any
    spec: Any
    # trainer
    init_train: Callable[[Any], TrainState]
    update: Callable  # (train, buffer, key) -> (train, buffer, metrics)
    # executor
    select_actions: Callable  # (train, obs, state, carry, key, training) -> (actions, carry, extras)
    initial_carry: Callable   # (batch_shape) -> carry
    # dataset
    init_buffer: Callable[[int], Any]  # (num_envs) -> buffer_state
    observe: Callable         # (buffer, transition_batch) -> buffer
    can_sample: Callable      # (buffer,) -> bool scalar (ready to update)
    # schedule
    updates_per_step: int = 1
    name: str = "system"
    # action-space support declared by the algorithm ("discrete"/"continuous")
    action_space: str = "discrete"


def _training_env(env):
    """The runner-side wrapper stack: episode stats over fused auto-reset.

    The runners used to hand-roll reset/global-state plumbing (select-where
    auto-resets, python-side return accumulators); it now composes from the
    `repro.envs.wrappers` stack, shared by every env and runner.
    """
    return EpisodeStats(AutoReset(env))


def _team_return(last_returns):
    """Mean-over-agents of the per-agent completed-episode returns."""
    return jnp.mean(jnp.stack(list(last_returns.values())), axis=0)


# ------------------------------------------------------ faithful python loop


def run_environment_loop(
    system: System,
    key,
    num_episodes: int = 10,
    training: bool = True,
    train_state: Optional[TrainState] = None,
    buffer_state=None,
):
    """The paper's Block-1 executor-environment loop, one env, python-paced.

    Returns (train_state, buffer_state, EvalMetrics over the episodes) —
    per-agent and team (mean-over-agents) undiscounted returns, accumulated
    by the `EpisodeStats` wrapper rather than python-side bookkeeping.
    """
    env = EpisodeStats(system.env)
    ids = list(system.spec.agent_ids)
    key, k_init = jax.random.split(key)
    if train_state is None:
        train_state = system.init_train(k_init)
    if buffer_state is None:
        buffer_state = system.init_buffer(1)

    select = jax.jit(functools.partial(system.select_actions, training=training))
    observe = jax.jit(system.observe)
    update = jax.jit(system.update)
    reset = jax.jit(env.reset)
    step_env = jax.jit(env.step)
    gstate = jax.jit(env.global_state)

    team_returns, lengths = [], []
    agent_returns = {a: [] for a in ids}
    for _ in range(num_episodes):
        key, k_reset = jax.random.split(key)
        # make initial observation for each agent
        env_state, ts = reset(k_reset)
        carry = system.initial_carry(())
        while int(ts.step_type) != StepType.LAST:
            key, k_act, k_upd = jax.random.split(key, 3)
            obs = ts.observation
            gs = gstate(env_state)
            actions, carry, extras = select(train_state, obs, gs, carry, k_act)
            new_env_state, new_ts = step_env(env_state, actions)
            if training:
                # make an observation for each agent (adder -> dataset)
                tr = Transition(
                    obs=obs,
                    actions=actions,
                    rewards=new_ts.reward,
                    discount=new_ts.discount,
                    next_obs=new_ts.observation,
                    state=gs,
                    next_state=gstate(new_env_state),
                    extras=extras,
                    step_type=ts.step_type,
                )
                tr_b = jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], tr)
                buffer_state = observe(buffer_state, tr_b)
                # update the trainer (and the executor's policy networks)
                if bool(system.can_sample(buffer_state)):
                    train_state, buffer_state, _ = update(
                        train_state, buffer_state, k_upd
                    )
            env_state, ts = new_env_state, new_ts
        for a in ids:
            agent_returns[a].append(float(env_state.last_returns[a]))
        team_returns.append(float(_team_return(env_state.last_returns)))
        lengths.append(int(env_state.last_length))
    metrics = EvalMetrics(
        episode_return=np.asarray(team_returns),
        agent_returns={a: np.asarray(agent_returns[a]) for a in ids},
        episode_length=np.asarray(lengths, np.int32),
    )
    return train_state, buffer_state, metrics


# ------------------------------------------------------------ Anakin runner


def _act_phase(system: System, tenv, train, env_state, timestep, carry, key):
    """One vectorised acting step under ``train``'s policy — no dataset write.

    The executor half of an iteration: refresh auto-reset randomness from
    the runner key, select actions, step every env, assemble the resulting
    `Transition` batch and zero executor carries at auto-reset FIRST
    boundaries (the memory-core protocol's one reset-masking rule).

    This is the exact acting computation `_step_phase` wraps; the async
    actor/learner runner (`repro.distributed.impala`) replays it verbatim
    with a *snapshot* train state, which is what makes the staleness-0
    async run bitwise-reproduce anakin's update sequence.

    Returns ``(env_state, timestep, carry, next_key, transition, k_upd,
    metrics)`` — ``k_upd`` is the update key this step would use if its
    transition completes a batch (the callers own the update gate).
    """
    key, k_act, k_upd, k_reset = jax.random.split(key, 4)
    num_envs = jax.tree_util.tree_leaves(env_state)[0].shape[0]
    env_state = replace_reset_keys(
        env_state, jax.random.split(k_reset, num_envs)
    )

    obs = timestep.observation
    gs = jax.vmap(tenv.global_state)(env_state)
    actions, new_carry, extras = system.select_actions(
        train, obs, gs, carry, k_act, training=True
    )
    new_env_state, new_ts = jax.vmap(tenv.step)(env_state, actions)
    tr = Transition(
        obs=obs,
        actions=actions,
        rewards=new_ts.reward,
        discount=new_ts.discount,
        next_obs=new_ts.observation,
        state=gs,
        next_state=jax.vmap(tenv.global_state)(new_env_state),
        extras=extras,
        step_type=timestep.step_type,
    )

    # a FIRST out of step marks an auto-reset boundary: executor carries
    # (recurrent cores, comm messages) restart with the new episode
    done = new_ts.step_type == StepType.FIRST
    new_carry = reset_carry(
        new_carry, done, initial=system.initial_carry((num_envs,))
    )

    ep_reward = jnp.mean(jnp.stack(list(new_ts.reward.values())))
    done_f = done.astype(jnp.float32)
    # mean return of the episodes that completed this iteration (0 if none)
    ep_return = jnp.sum(
        _team_return(new_env_state.last_returns) * done_f
    ) / jnp.maximum(jnp.sum(done_f), 1.0)
    metrics = {
        "reward": ep_reward,
        "done_frac": jnp.mean(done_f),
        "episode_return": ep_return,
    }
    return new_env_state, new_ts, new_carry, key, tr, k_upd, metrics


def _step_phase(system: System, tenv, st: SystemState, key):
    """Everything in one iteration *except* the trainer update.

    ``tenv`` is the wrapper stack from `_training_env`: `AutoReset` fuses
    episode boundaries into the step (a terminated env returns the FIRST
    timestep of its next episode, carrying the terminal reward/discount)
    and `EpisodeStats` accumulates completed-episode returns — so the
    runner has no reset plumbing of its own.  Auto-reset randomness is
    refreshed from the runner key every iteration, keeping training a
    reproducible function of the runner key alone.

    Acting is `_act_phase`; this wrapper adds the dataset write
    (``system.observe``).  Returns (SystemState with the *old* train
    state, update key, metrics); the callers own the update gate so the
    seed-vectorized runner can hoist it out of the lane axis (see
    `_one_iteration_seeds`).
    """
    env_state, ts, carry, key, tr, k_upd, metrics = _act_phase(
        system, tenv, st.train, st.env_state, st.timestep, st.carry, key
    )
    buffer = system.observe(st.buffer, tr)
    st = SystemState(st.train, buffer, env_state, ts, carry, key)
    return st, k_upd, metrics


def _do_updates(system: System, train, buffer, k_upd):
    """``updates_per_step`` trainer updates (the gated branch body)."""
    for i in range(system.updates_per_step):
        train, buffer, _ = system.update(
            train, buffer, jax.random.fold_in(k_upd, i)
        )
    return train, buffer


def _one_iteration(system: System, tenv, carry, key):
    """One vectorised step of every env + gated updates. carry = SystemState.

    The trainer update(s) are gated on buffer readiness (replay fill, or a
    complete rollout — in which case update consumes and resets it).
    """
    st, k_upd, metrics = _step_phase(system, tenv, carry, key)
    train, buffer = jax.lax.cond(
        system.can_sample(st.buffer),
        lambda tb: _do_updates(system, tb[0], tb[1], k_upd),
        lambda tb: tb,
        (st.train, st.buffer),
    )
    return st._replace(train=train, buffer=buffer), metrics


def _one_iteration_seeds(system: System, tenv, carry, keys):
    """Seed-batched `_one_iteration`: every SystemState leaf and ``keys``
    carry a leading ``(num_seeds,)`` lane axis.

    Stepping is vmapped per lane, but the update gate is hoisted *out* of
    the lane axis: under a plain vmap the per-lane `lax.cond` lowers to
    `select`, executing both branches every iteration — for rollout systems
    that means the full consume-and-reset update every step instead of every
    ``rollout_len`` steps, destroying the fused program's speed.  All three
    experience regimes advance their schedules data-independently (replay
    fill, rollout cursors and sequence-window counts move identically in
    every lane — `seq_expected_size` is the closed form tests pin), so all
    lanes agree and one scalar cond preserves the serial runner's exact
    update cadence.
    """
    st, k_upd, metrics = jax.vmap(
        functools.partial(_step_phase, system, tenv)
    )(carry, keys)
    ready = jax.vmap(system.can_sample)(st.buffer)
    train, buffer = jax.lax.cond(
        jnp.all(ready),
        lambda tb: jax.vmap(
            functools.partial(_do_updates, system)
        )(tb[0], tb[1], k_upd),
        lambda tb: tb,
        (st.train, st.buffer),
    )
    return st._replace(train=train, buffer=buffer), metrics


def seed_keys(key, num_seeds: int):
    """A ``(num_seeds,)`` batch of per-seed PRNG keys.

    Accepts either a single key (split into ``num_seeds`` independent
    streams) or an already-stacked batch, returned as-is — the sweep stacks
    ``jax.random.key(s)`` per seed so each vmapped lane sees exactly the key
    the serial path would have.
    """
    key = jnp.asarray(key)
    batch_ndim = 1 if jnp.issubdtype(key.dtype, jax.dtypes.prng_key) else 2
    if key.ndim == batch_ndim:
        if key.shape[0] != num_seeds:
            raise ValueError(
                f"got a batch of {key.shape[0]} keys for num_seeds={num_seeds}"
            )
        return key
    return jax.random.split(key, num_seeds)


def init_system_state(
    system: System, key, num_envs: int, train_env=None, num_seeds: Optional[int] = None
) -> SystemState:
    """Fresh SystemState; with ``num_seeds`` every leaf gains a leading seed
    axis (one independent run per key from `seed_keys`)."""
    tenv = train_env if train_env is not None else _training_env(system.env)
    if num_seeds is not None:
        return jax.vmap(
            lambda k: init_system_state(system, k, num_envs, train_env=tenv)
        )(seed_keys(key, num_seeds))
    k_train, k_env, k_sys = jax.random.split(key, 3)
    env_state, ts = jax.vmap(tenv.reset)(jax.random.split(k_env, num_envs))
    return SystemState(
        train=system.init_train(k_train),
        buffer=system.init_buffer(num_envs),
        env_state=env_state,
        timestep=ts,
        carry=system.initial_carry((num_envs,)),
        key=k_sys,
    )


def _tap_body(iterate_fn, log_every: int, log_callback):
    """Wrap a scan body with the in-jit telemetry tap (a pure observer).

    The wrapped body is scanned over the iteration index; every
    ``log_every`` iterations a `jax.debug.callback` ships the iteration
    index, the trainer's update counter and the per-iteration metrics to
    the host (``log_callback``, typically a `repro.obs.MetricTap`).  The
    callback has no outputs, so nothing can flow back into the program —
    taps-on and taps-off runs stay bitwise-identical (pinned in
    tests/test_bench.py) — and the `lax.cond` keeps non-logging iterations
    free of host traffic.
    """

    def body(carry, it):
        st, metrics = iterate_fn(carry)
        jax.lax.cond(
            (it + 1) % log_every == 0,
            lambda: jax.debug.callback(
                log_callback, it, st.train.steps, metrics
            ),
            lambda: None,
        )
        return st, metrics

    return body


def make_anakin(
    system: System,
    num_iterations: int,
    num_envs: int,
    eval_every: int = 0,
    eval_episodes: int = 32,
    eval_num_envs: Optional[int] = None,
    num_seeds: Optional[int] = None,
    log_every: int = 0,
    log_callback=None,
):
    """Build the fused Anakin program as a reusable function of ``key``.

    The returned ``program(key)`` is what `train_anakin` calls once; holding
    on to it amortises compilation across calls (the benchmark's serial-seed
    baseline) because the jit cache is keyed on the closure object.  The
    scanned carry is donated, so each call's SystemState buffers are reused
    in place rather than copied.  ``program.fused`` / ``program.init_fn``
    expose the underlying jits for AOT inspection (the ``--profile``
    roofline path lowers ``fused`` without running it).

    With ``num_seeds`` the whole program — init, training scan and any
    interleaved eval — is vmapped over a leading seed axis: N independent
    runs execute as one fused jit program (the JaxMARL vmap-over-seeds
    idiom), and every output leaf gains a leading ``(num_seeds,)`` axis.
    ``key`` may then be a single key (split per seed) or a stacked
    ``(num_seeds,)`` key batch for exact parity with serial runs.

    With ``log_every > 0`` and a ``log_callback``, the scan streams
    in-flight telemetry to the host every ``log_every`` iterations via
    `jax.debug.callback` — live progress out of an otherwise silent jit,
    without perturbing it (see `_tap_body`).  When off (the default) the
    scan body is byte-for-byte the untapped program.
    """
    tenv = _training_env(system.env)
    iterate = _one_iteration if num_seeds is None else _one_iteration_seeds
    tapping = log_every > 0 and log_callback is not None

    def _iterate(st):
        return iterate(system, tenv, st, st.key)

    if tapping:
        tapped = _tap_body(_iterate, log_every, log_callback)

        def train_body(carry, it):
            return tapped(carry, it)
    else:
        def train_body(carry, _):
            return _iterate(carry)

    # a seed-batched scan stacks metrics time-major (T, S, ...); promised
    # axis order is seed-major, matching N stacked serial runs
    def seed_major(x):
        return x if num_seeds is None else jnp.moveaxis(x, 0, 1)

    if eval_every <= 0:
        def run(st):
            xs = jnp.arange(num_iterations) if tapping else None
            st, metrics = jax.lax.scan(train_body, st, xs, length=num_iterations)
            return st, jax.tree_util.tree_map(seed_major, metrics)
    else:
        if num_iterations % eval_every:
            raise ValueError(
                f"num_iterations ({num_iterations}) must be a multiple of "
                f"eval_every ({eval_every})"
            )
        num_blocks = num_iterations // eval_every
        # local import: repro.eval's sweep harness imports this module back
        from repro.eval.evaluator import make_evaluator

        eval_fn = make_evaluator(system, eval_episodes, eval_num_envs or num_envs)

        def run(st):
            def block(st, b):
                # global iteration indices for the tap; None leaves the
                # untapped block scan untouched
                xs = b * eval_every + jnp.arange(eval_every) if tapping else None
                st, metrics = jax.lax.scan(train_body, st, xs, length=eval_every)
                if num_seeds is None:
                    k_eval, k_next = jax.random.split(st.key)
                    ev = eval_fn(st.train, k_eval)
                else:
                    split = jax.vmap(jax.random.split)(st.key)
                    k_eval, k_next = split[:, 0], split[:, 1]
                    ev = jax.vmap(eval_fn)(st.train, k_eval)
                return st._replace(key=k_next), (metrics, ev)

            bxs = jnp.arange(num_blocks) if tapping else None
            st, (metrics, evals) = jax.lax.scan(block, st, bxs, length=num_blocks)
            # (num_blocks, eval_every, [S,] ...) -> ([S,] num_iterations, ...)
            metrics = jax.tree_util.tree_map(
                lambda x: seed_major(
                    x.reshape((num_iterations,) + x.shape[2:])
                ),
                metrics,
            )
            # eval points: (num_blocks, [S,] E) -> ([S,] num_blocks, E)
            evals = jax.tree_util.tree_map(seed_major, evals)
            return st, metrics, evals

    init_fn = jax.jit(
        lambda key: _unalias(
            init_system_state(
                system, key, num_envs, train_env=tenv, num_seeds=num_seeds
            )
        )
    )
    fused = jax.jit(run, donate_argnums=0)

    def program(key):
        return fused(init_fn(key))

    # AOT handles for observability tooling (HLO-cost summaries, traces)
    program.fused = fused
    program.init_fn = init_fn
    return program


def _unalias(tree):
    """Copy leaves that appear more than once so the tree can be donated.

    `init_train` aliases ``target_params`` to ``params`` at step 0; donating
    a pytree containing one buffer twice is an XLA error.  Applied *inside*
    the jitted init, where duplicated leaves are literally the same tracer
    (so the ``id`` check fires and inserts a copy), guaranteeing the
    returned state has distinct output buffers on every backend.
    """
    seen: set = set()

    def uniq(x):
        if id(x) in seen:
            return jnp.array(x)
        seen.add(id(x))
        return x

    return jax.tree_util.tree_map(uniq, tree)


def train_anakin(
    system: System,
    key,
    num_iterations: int,
    num_envs: int,
    eval_every: int = 0,
    eval_episodes: int = 32,
    eval_num_envs: Optional[int] = None,
    num_seeds: Optional[int] = None,
    log_every: int = 0,
    log_callback=None,
):
    """Fused jit training: scan(num_iterations) x vmap(num_envs).

    Returns (final SystemState, metrics stacked over iterations).

    With ``eval_every > 0`` the greedy evaluator (`repro.eval`) runs inside
    the same jit every `eval_every` iterations — no host round trip — and
    the return becomes (state, metrics, EvalMetrics stacked over the
    num_iterations // eval_every eval points).  Each eval uses the first
    half of a split of the post-block scan key, so its returns are
    reproducible by the standalone `repro.eval.evaluate` given the same
    train state and key.

    With ``num_seeds`` set, N independent seeds train simultaneously in one
    compiled program (vmap over per-seed SystemState); every return leaf
    gains a leading ``(num_seeds,)`` axis and per-seed lanes are the runs
    the serial path would produce from the same per-seed keys.  ``key`` may
    be a single key or a stacked ``(num_seeds,)`` batch (see `seed_keys`).

    ``log_every``/``log_callback`` install the in-flight telemetry tap
    (see `make_anakin`): metrics stream to the host mid-scan without
    changing a single bit of the run's results.  Unlike the raw
    `make_anakin` program, this wrapper drains the callback queue before
    returning (``jax.debug.callback`` is async), so every due emission
    has landed by the time the caller reads its tap.
    """
    out = make_anakin(
        system,
        num_iterations,
        num_envs,
        eval_every=eval_every,
        eval_episodes=eval_episodes,
        eval_num_envs=eval_num_envs,
        num_seeds=num_seeds,
        log_every=log_every,
        log_callback=log_callback,
    )(key)
    if log_every > 0 and log_callback is not None:
        jax.block_until_ready(out)
        jax.effects_barrier()
    return out


# -------------------------------------------------------- distributed runner


def make_distributed(
    system: System,
    num_iterations: int,
    num_envs_per_device: int,
    mesh,
    axis: str = "data",
    eval_episodes: int = 0,
    eval_num_envs: Optional[int] = None,
    log_every: int = 0,
    log_callback=None,
):
    """Build the shard_map training program as a reusable function of ``key``.

    `train_distributed` calls it once; the benchmark holds on to it so timed
    calls hit the jit cache instead of re-tracing the SPMD program.

    ``log_every``/``log_callback`` stream in-flight metrics exactly as in
    `make_anakin`; under shard_map the callback fires per device shard, so
    the host tap sees each executor's local metrics (callers that want one
    line per emission should aggregate in their logger).

    Like `make_anakin`, the program is split into an init jit and a
    training jit (``program.init_fn`` / ``program.fused``), so repeat
    calls — the benchmark's timed calls in particular — re-run only the
    training scan.  The earlier one-jit form re-built every device's
    SystemState inside each call, which is why committed BENCH_speed
    tables showed shard_map trailing anakin on some cells (see
    docs/DISTRIBUTED.md).  Unlike anakin's fused jit the training jit is
    *not* donated: its outputs are reductions (replicated params + mean
    metrics), so there are no output buffers the state could alias —
    donation would only produce "unusable donation" warnings.
    """
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.shape[axis]

    eval_fn = None
    if eval_episodes > 0:
        # local import: repro.eval's sweep harness imports this module back
        from repro.eval.evaluator import make_evaluator

        eval_fn = make_evaluator(
            system, eval_episodes, eval_num_envs or num_envs_per_device
        )

    tenv = _training_env(system.env)

    tapping = log_every > 0 and log_callback is not None

    def per_device_init(dev_keys):
        st = init_system_state(
            system, dev_keys[0], num_envs_per_device, train_env=tenv
        )
        # every leaf gains a leading per-device axis of 1 so the state can
        # cross the shard_map boundary sharded on the data axis (scalars
        # included — P(axis) cannot shard a rank-0 leaf)
        return jax.tree_util.tree_map(lambda x: x[None], _unalias(st))

    def per_device_run(st_batched):
        st = jax.tree_util.tree_map(lambda x: x[0], st_batched)

        def _iterate(st):
            return _one_iteration(system, tenv, st, st.key)

        if tapping:
            tapped = _tap_body(_iterate, log_every, log_callback)

            def body(carry, it):
                return tapped(carry, it)
        else:
            def body(carry, _):
                return _iterate(carry)

        xs = jnp.arange(num_iterations) if tapping else None
        st, metrics = jax.lax.scan(body, st, xs, length=num_iterations)
        # return replicated params + per-device mean reward (rank-1 so the
        # data axis can concatenate device results)
        out = st.train.params, jax.tree_util.tree_map(
            lambda x: jnp.mean(x)[None], metrics
        )
        if eval_fn is not None:
            k_eval, _ = jax.random.split(st.key)
            ev = eval_fn(st.train, k_eval)
            out = out + (jnp.mean(ev.episode_return)[None],)
        return out

    init_fn = jax.jit(
        shard_map(
            per_device_init,
            mesh=mesh,
            in_specs=(P(axis),),
            out_specs=P(axis),
            check_rep=False,
        )
    )
    out_specs = (P(), P(axis)) if eval_fn is None else (P(), P(axis), P(axis))
    fused = jax.jit(
        shard_map(
            per_device_run,
            mesh=mesh,
            in_specs=(P(axis),),
            out_specs=out_specs,
            check_rep=False,
        )
    )

    def program(key):
        return fused(init_fn(jax.random.split(key, n_dev)))

    program.fused = fused
    program.init_fn = init_fn
    return program


def train_distributed(
    system: System,
    key,
    num_iterations: int,
    num_envs_per_device: int,
    mesh,
    axis: str = "data",
    eval_episodes: int = 0,
    eval_num_envs: Optional[int] = None,
    log_every: int = 0,
    log_callback=None,
):
    """shard_map over the mesh data axis: paper's num_executors scaling.

    Each device runs its own envs + buffer shard; the system's update must
    pmean gradients over `axis` (systems built with distributed=True do).
    Params start replicated and stay replicated.

    With ``eval_episodes > 0`` every device additionally runs the fused
    greedy evaluator on the final (replicated) params inside the same SPMD
    program, and the return becomes (params, metrics, per-device mean eval
    return of shape (num_devices,)).

    When the telemetry tap is installed this wrapper drains the callback
    queue before returning (``jax.debug.callback`` is async), so every due
    emission has landed by the time the caller reads its tap.
    """
    out = make_distributed(
        system,
        num_iterations,
        num_envs_per_device,
        mesh,
        axis=axis,
        eval_episodes=eval_episodes,
        eval_num_envs=eval_num_envs,
        log_every=log_every,
        log_callback=log_callback,
    )(key)
    if log_every > 0 and log_callback is not None:
        jax.block_until_ready(out)
        jax.effects_barrier()
    return out
