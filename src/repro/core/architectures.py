"""System architectures: the information-flow layer of a Mava system.

An architecture decides what each agent's policy and critic may condition
on (paper Fig. 3):

  Decentralised — policy_i(o_i);    critic_i(o_i, a_i)
  Centralised   — policy_i(o_i);    critic_i(global_state, a_1..a_N)
  Networked     — policy_i(o_i);    critic_i(o_i ∪ o_j, a_j for j in N(i))

Architectures are pure input-builders, so wrapping modules (communication,
fingerprints) compose by transforming the returned arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax.numpy as jnp


def one_hot_actions(actions: Dict[str, jnp.ndarray], num_actions: Dict[str, int]):
    import jax.nn

    return {
        a: jax.nn.one_hot(actions[a], num_actions[a]) for a in actions
    }


@dataclasses.dataclass(frozen=True)
class DecentralisedPolicyActor:
    """Fully independent agents (paper Fig. 3 left)."""

    def policy_input(self, obs, agent):
        return obs[agent]

    def critic_input(self, obs, actions_oh, global_state, agent):
        return jnp.concatenate([obs[agent], actions_oh[agent]], axis=-1)


@dataclasses.dataclass(frozen=True)
class CentralisedQValueCritic:
    """CTDE: critics see the global state and all agents' actions."""

    agent_order: Sequence[str] = ()

    def policy_input(self, obs, agent):
        return obs[agent]

    def critic_input(self, obs, actions_oh, global_state, agent):
        order = self.agent_order or sorted(obs.keys())
        all_acts = jnp.concatenate([actions_oh[a] for a in order], axis=-1)
        return jnp.concatenate([global_state, all_acts], axis=-1)


@dataclasses.dataclass(frozen=True)
class NetworkedQValueCritic:
    """Information topology: critic_i sees its graph neighbourhood only.

    adjacency[i][j] = 1 when agent j's obs/action flow into agent i's critic
    (the diagonal should be 1). Row order follows agent_order.
    """

    adjacency: tuple  # tuple of tuples of 0/1
    agent_order: Sequence[str] = ()

    def policy_input(self, obs, agent):
        return obs[agent]

    def critic_input(self, obs, actions_oh, global_state, agent):
        order = list(self.agent_order or sorted(obs.keys()))
        i = order.index(agent)
        feats = []
        for j, other in enumerate(order):
            m = float(self.adjacency[i][j])
            feats.append(obs[other] * m)
            feats.append(actions_oh[other] * m)
        return jnp.concatenate(feats, axis=-1)
