"""Replay stabilisation via policy fingerprints (Foerster et al. 2017c).

Independent-learner replay is non-stationary: old transitions were generated
under other agents' older policies. The fingerprint disambiguates them by
appending a low-dimensional signature of the joint policy — here (epsilon,
trainer_step) — to each observation, both when acting and when training.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FingerPrintStabilisation:
    step_scale: float = 1e-4  # trainer steps are O(1e4)

    @property
    def size(self) -> int:
        return 2

    def augment(self, obs: Dict[str, jnp.ndarray], eps, step):
        """Append [eps, step*scale] to every agent's observation."""
        def aug(o):
            fp = jnp.stack(
                [
                    jnp.broadcast_to(eps, o.shape[:-1]),
                    jnp.broadcast_to(step * self.step_scale, o.shape[:-1]),
                ],
                axis=-1,
            ).astype(o.dtype)
            return jnp.concatenate([o, fp], axis=-1)

        return {a: aug(o) for a, o in obs.items()}
