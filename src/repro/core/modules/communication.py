"""Learned-communication modules (DIAL).

The Discretise/Regularise Unit (DRU) from Foerster et al. 2016: during
(centralised) training the channel is continuous — sigmoid(m + noise) — so
gradients flow between agents through the channel; during decentralised
execution the message is hard-thresholded to a bit. BroadcastedCommunication
routes each agent's outgoing message to all other agents (mean-pooled),
optionally with a shared channel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


def dru(message, key, noise_std: float, training: bool):
    """Discretise/Regularise Unit."""
    if training:
        noise = jax.random.normal(key, message.shape) * noise_std
        return jax.nn.sigmoid(message + noise)
    return (message > 0).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class BroadcastedCommunication:
    channel_size: int = 1
    noise_std: float = 0.5
    shared: bool = True  # one shared channel: messages are mean-pooled

    def route(self, messages: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """messages: per-agent outgoing (..., C) -> per-agent incoming."""
        ids = sorted(messages.keys())
        stack = jnp.stack([messages[a] for a in ids], axis=0)  # (N, ..., C)
        N = len(ids)
        if self.shared:
            total = jnp.sum(stack, axis=0, keepdims=True)
            incoming = (total - stack) / max(N - 1, 1)
        else:
            # each agent hears the concat of all other agents' channels
            incoming = jnp.stack(
                [
                    jnp.concatenate(
                        [stack[j] for j in range(N) if j != i], axis=-1
                    )
                    for i in range(N)
                ],
                axis=0,
            )
        return {a: incoming[i] for i, a in enumerate(ids)}

    def incoming_size(self, num_agents: int) -> int:
        return self.channel_size if self.shared else self.channel_size * (
            num_agents - 1
        )
