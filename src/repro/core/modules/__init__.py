from repro.core.modules.mixing import AdditiveMixing, MonotonicMixing
from repro.core.modules.communication import BroadcastedCommunication, dru
from repro.core.modules.stabilisation import FingerPrintStabilisation

__all__ = [
    "AdditiveMixing",
    "MonotonicMixing",
    "BroadcastedCommunication",
    "dru",
    "FingerPrintStabilisation",
]
