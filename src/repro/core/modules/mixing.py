"""Value-decomposition mixing modules (VDN / QMIX).

A mixing module maps per-agent chosen Q-values (and the global state) to a
joint Q_tot used in the TD loss. AdditiveMixing is VDN's sum; MonotonicMixing
is QMIX's state-conditioned hypernetwork with non-negative mixing weights
(which guarantees ∂Q_tot/∂Q_i ≥ 0 — property-tested in
tests/test_mixing.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import initializers


@dataclasses.dataclass(frozen=True)
class AdditiveMixing:
    """VDN: Q_tot = sum_i Q_i. Stateless."""

    def init(self, key, num_agents: int, state_dim: int):
        del key, num_agents, state_dim
        return {}

    def apply(self, params, agent_qs, state):
        """agent_qs: (..., N); state: (..., S) unused -> (...,)."""
        del params, state
        return jnp.sum(agent_qs, axis=-1)


@dataclasses.dataclass(frozen=True)
class MonotonicMixing:
    """QMIX: Q_tot = w2(s)^T elu(w1(s)^T q + b1(s)) + b2(s), w1,w2 >= 0."""

    embed_dim: int = 32
    hypernet_hidden: int = 64

    def init(self, key, num_agents: int, state_dim: int):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        lecun = initializers.lecun_normal()
        E, H = self.embed_dim, self.hypernet_hidden
        return {
            "hyper_w1": lecun(k1, (state_dim, num_agents * E)),
            "hyper_b1": jnp.zeros((state_dim, E)),
            "hyper_w2": lecun(k2, (state_dim, E)),
            # b2 is a 2-layer hypernetwork (as in the QMIX paper)
            "hyper_b2_1": lecun(k3, (state_dim, H)),
            "hyper_b2_1b": jnp.zeros((H,)),
            "hyper_b2_2": lecun(k4, (H, 1)),
        }

    def apply(self, params, agent_qs, state):
        """agent_qs: (..., N); state: (..., S) -> (...,)."""
        N = agent_qs.shape[-1]
        E = self.embed_dim
        w1 = jnp.abs(state @ params["hyper_w1"]).reshape(*state.shape[:-1], N, E)
        b1 = state @ params["hyper_b1"]
        hidden = jax.nn.elu(
            jnp.einsum("...n,...ne->...e", agent_qs, w1) + b1
        )
        w2 = jnp.abs(state @ params["hyper_w2"])
        b2 = (
            jax.nn.relu(state @ params["hyper_b2_1"] + params["hyper_b2_1b"])
            @ params["hyper_b2_2"]
        )[..., 0]
        return jnp.sum(hidden * w2, axis=-1) + b2
