"""On-device replay tables — the Reverb replacement (see DESIGN.md §3).

Fixed-capacity circular storage as a pytree of arrays with a functional
add/sample API, so the whole table lives in the training jit. Supports the
FIFO overwrite discipline of a bounded Reverb table and uniform sampling;
a trajectory variant stores fixed-length sequences for recurrent systems
(R2D2-style MADQN, DIAL).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class BufferState(NamedTuple):
    storage: Any          # pytree, leaves (capacity, ...)
    insert_pos: jnp.ndarray
    size: jnp.ndarray


def buffer_init(example_item, capacity: int) -> BufferState:
    """example_item: a pytree with the per-item shapes (no leading dim)."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example_item,
    )
    return BufferState(
        storage=storage,
        insert_pos=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def buffer_add(state: BufferState, items) -> BufferState:
    """Add a batch of items (leading dim B), overwriting FIFO on overflow."""
    leaves = jax.tree_util.tree_leaves(items)
    B = leaves[0].shape[0]
    capacity = jax.tree_util.tree_leaves(state.storage)[0].shape[0]
    idx = (state.insert_pos + jnp.arange(B)) % capacity
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[idx].set(x.astype(s.dtype)), state.storage, items
    )
    return BufferState(
        storage=storage,
        insert_pos=(state.insert_pos + B) % capacity,
        size=jnp.minimum(state.size + B, capacity),
    )


def buffer_sample(state: BufferState, key, batch_size: int):
    """Uniform sample with replacement over the filled region."""
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(lambda s: s[idx], state.storage)


def buffer_can_sample(state: BufferState, min_size: int):
    return state.size >= min_size


# --------------------------------------------------------------------------
# On-policy rollout accumulator: the second experience regime of the dataset
# protocol. Where the replay table above stores i.i.d.-sampled rows, this
# stores a time-major (rollout_len, num_envs, ...) trajectory that the
# trainer consumes whole (GAE / BPTT need the time axis) and then resets —
# the `rollout_len`-gated consume-and-reset contract used by PPO and DIAL.


class RolloutState(NamedTuple):
    storage: Any          # pytree, leaves (rollout_len, num_envs, ...)
    t: jnp.ndarray        # () int32 — next write slot (t == T means full)


def rollout_init(example_item, rollout_len: int, num_envs: int) -> RolloutState:
    """example_item: a pytree with per-item shapes (no time/env dims)."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros(
            (rollout_len, num_envs) + jnp.shape(x), jnp.asarray(x).dtype
        ),
        example_item,
    )
    return RolloutState(storage=storage, t=jnp.zeros((), jnp.int32))


def rollout_add(state: RolloutState, items) -> RolloutState:
    """Append one vectorised step (leaves (num_envs, ...)) at the cursor.

    Writes past the end are dropped (JAX out-of-bounds scatter semantics),
    so a full rollout is safe until the trainer consumes and resets it.
    """
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[state.t].set(x.astype(s.dtype)), state.storage, items
    )
    return RolloutState(storage=storage, t=state.t + 1)


def rollout_ready(state: RolloutState, rollout_len: int):
    """True once the accumulator holds a complete rollout."""
    return state.t >= rollout_len


def rollout_take(state: RolloutState):
    """The full time-major trajectory (leaves (rollout_len, num_envs, ...))."""
    return state.storage


def rollout_reset(state: RolloutState) -> RolloutState:
    """Consume: rewind the cursor (storage is overwritten in place)."""
    return RolloutState(storage=state.storage, t=jnp.zeros((), jnp.int32))
