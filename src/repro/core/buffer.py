"""On-device replay tables — the Reverb replacement (see DESIGN.md §3).

Fixed-capacity circular storage as a pytree of arrays with a functional
add/sample API, so the whole table lives in the training jit. Supports the
FIFO overwrite discipline of a bounded Reverb table and uniform sampling;
a trajectory variant stores fixed-length sequences for recurrent systems
(R2D2-style MADQN, DIAL).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class BufferState(NamedTuple):
    storage: Any          # pytree, leaves (capacity, ...)
    insert_pos: jnp.ndarray
    size: jnp.ndarray


def buffer_init(example_item, capacity: int) -> BufferState:
    """example_item: a pytree with the per-item shapes (no leading dim)."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example_item,
    )
    return BufferState(
        storage=storage,
        insert_pos=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def buffer_add(state: BufferState, items) -> BufferState:
    """Add a batch of items (leading dim B), overwriting FIFO on overflow."""
    leaves = jax.tree_util.tree_leaves(items)
    B = leaves[0].shape[0]
    capacity = jax.tree_util.tree_leaves(state.storage)[0].shape[0]
    idx = (state.insert_pos + jnp.arange(B)) % capacity
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[idx].set(x.astype(s.dtype)), state.storage, items
    )
    return BufferState(
        storage=storage,
        insert_pos=(state.insert_pos + B) % capacity,
        size=jnp.minimum(state.size + B, capacity),
    )


def buffer_sample(state: BufferState, key, batch_size: int):
    """Uniform sample with replacement over the filled region."""
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(lambda s: s[idx], state.storage)


def buffer_can_sample(state: BufferState, min_size: int):
    return state.size >= min_size


# --------------------------------------------------------------------------
# On-policy rollout accumulator: the second experience regime of the dataset
# protocol. Where the replay table above stores i.i.d.-sampled rows, this
# stores a time-major (rollout_len, num_envs, ...) trajectory that the
# trainer consumes whole (GAE / BPTT need the time axis) and then resets —
# the `rollout_len`-gated consume-and-reset contract used by PPO and DIAL.


class RolloutState(NamedTuple):
    storage: Any          # pytree, leaves (rollout_len, num_envs, ...)
    t: jnp.ndarray        # () int32 — next write slot (t == T means full)


def rollout_init(example_item, rollout_len: int, num_envs: int) -> RolloutState:
    """example_item: a pytree with per-item shapes (no time/env dims)."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros(
            (rollout_len, num_envs) + jnp.shape(x), jnp.asarray(x).dtype
        ),
        example_item,
    )
    return RolloutState(storage=storage, t=jnp.zeros((), jnp.int32))


def rollout_add(state: RolloutState, items) -> RolloutState:
    """Append one vectorised step (leaves (num_envs, ...)) at the cursor.

    Writes past the end are dropped (JAX out-of-bounds scatter semantics),
    so a full rollout is safe until the trainer consumes and resets it.
    """
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[state.t].set(x.astype(s.dtype)), state.storage, items
    )
    return RolloutState(storage=storage, t=state.t + 1)


def rollout_ready(state: RolloutState, rollout_len: int):
    """True once the accumulator holds a complete rollout."""
    return state.t >= rollout_len


def rollout_take(state: RolloutState):
    """The full time-major trajectory (leaves (rollout_len, num_envs, ...))."""
    return state.storage


def rollout_reset(state: RolloutState) -> RolloutState:
    """Consume: rewind the cursor (storage is overwritten in place)."""
    return RolloutState(storage=state.storage, t=jnp.zeros((), jnp.int32))


# --------------------------------------------------------------------------
# Device-resident trajectory queue: the third structure of the experience
# protocol, used by the async actor/learner runner
# (`repro.distributed.impala`). Where the replay table and the rollout
# accumulator are *datasets* (owned by the learner), the queue is a
# *transport*: a fixed-capacity FIFO ring of trajectory-chunk slots that
# decouples actor production from learner consumption inside one fused jit.
# Items are arbitrary pytrees (a time-major Transition chunk plus update
# keys and staleness metadata); push to a full queue drops the incoming
# item (the runner counts drops), pop of an empty queue is gated by the
# caller on `queue_size`.


class QueueState(NamedTuple):
    """A fixed-capacity FIFO ring of pytree slots, fully device-resident."""

    storage: Any          # pytree, leaves (capacity, ...) — one slot per item
    head: jnp.ndarray     # () int32 — slot index of the oldest queued item
    size: jnp.ndarray     # () int32 — number of items currently queued


def queue_init(example_item, capacity: int) -> QueueState:
    """A fresh empty queue; ``example_item`` fixes slot shapes and dtypes."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example_item,
    )
    return QueueState(
        storage=storage,
        head=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def queue_capacity(state: QueueState) -> int:
    """The static number of slots the queue was built with."""
    return jax.tree_util.tree_leaves(state.storage)[0].shape[0]


def queue_size(state: QueueState):
    """How many items are currently queued (a traced scalar)."""
    return state.size


def queue_push(state: QueueState, item):
    """Enqueue one item at the tail; a full queue drops the *incoming* item.

    Returns ``(state, accepted)`` where ``accepted`` is a scalar bool —
    False means the item was dropped (bounded-queue backpressure; the
    async runner surfaces the drop count in its telemetry).
    """
    capacity = queue_capacity(state)
    ok = state.size < capacity
    slot = (state.head + state.size) % capacity
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[slot].set(
            jnp.where(ok, x.astype(s.dtype), s[slot])
        ),
        state.storage,
        item,
    )
    return (
        QueueState(
            storage=storage,
            head=state.head,
            size=state.size + ok.astype(jnp.int32),
        ),
        ok,
    )


def queue_pop(state: QueueState):
    """Dequeue the oldest item (FIFO).

    Returns ``(state, item)``.  Popping an empty queue returns the stale
    contents of the head slot and leaves the queue empty — callers gate on
    `queue_size` (the async runner wraps every pop in a ``lax.cond``).
    """
    capacity = queue_capacity(state)
    has = state.size > 0
    item = jax.tree_util.tree_map(lambda s: s[state.head], state.storage)
    return (
        QueueState(
            storage=state.storage,
            head=jnp.where(has, (state.head + 1) % capacity, state.head),
            size=state.size - has.astype(jnp.int32),
        ),
        item,
    )
