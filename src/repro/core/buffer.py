"""On-device replay tables — the Reverb replacement (see DESIGN.md §3).

Fixed-capacity circular storage as a pytree of arrays with a functional
add/sample API, so the whole table lives in the training jit. Four
structures share the idiom:

* `BufferState` — the flat per-step replay table (FIFO overwrite,
  uniform sampling) behind the feed-forward off-policy family;
* `RolloutState` — the on-policy time-major rollout accumulator
  (consume-and-reset);
* `SeqBufferState` — the sequence-replay table for *recurrent* off-policy
  systems (R2D2-style): fixed-length time-major windows cut from the
  incoming step stream with overlap striding, FIFO overwrite over whole
  windows, uniform window sampling;
* `QueueState` — the async runner's trajectory-chunk transport.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class BufferState(NamedTuple):
    storage: Any          # pytree, leaves (capacity, ...)
    insert_pos: jnp.ndarray
    size: jnp.ndarray


def buffer_init(example_item, capacity: int) -> BufferState:
    """example_item: a pytree with the per-item shapes (no leading dim)."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example_item,
    )
    return BufferState(
        storage=storage,
        insert_pos=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def buffer_add(state: BufferState, items) -> BufferState:
    """Add a batch of items (leading dim B), overwriting FIFO on overflow."""
    leaves = jax.tree_util.tree_leaves(items)
    B = leaves[0].shape[0]
    capacity = jax.tree_util.tree_leaves(state.storage)[0].shape[0]
    idx = (state.insert_pos + jnp.arange(B)) % capacity
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[idx].set(x.astype(s.dtype)), state.storage, items
    )
    return BufferState(
        storage=storage,
        insert_pos=(state.insert_pos + B) % capacity,
        size=jnp.minimum(state.size + B, capacity),
    )


def buffer_sample(state: BufferState, key, batch_size: int):
    """Uniform sample with replacement over the filled region."""
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(lambda s: s[idx], state.storage)


def buffer_can_sample(state: BufferState, min_size: int):
    return state.size >= min_size


# --------------------------------------------------------------------------
# On-policy rollout accumulator: the second experience regime of the dataset
# protocol. Where the replay table above stores i.i.d.-sampled rows, this
# stores a time-major (rollout_len, num_envs, ...) trajectory that the
# trainer consumes whole (GAE / BPTT need the time axis) and then resets —
# the `rollout_len`-gated consume-and-reset contract used by PPO and DIAL.


class RolloutState(NamedTuple):
    storage: Any          # pytree, leaves (rollout_len, num_envs, ...)
    t: jnp.ndarray        # () int32 — next write slot (t == T means full)


def rollout_init(example_item, rollout_len: int, num_envs: int) -> RolloutState:
    """example_item: a pytree with per-item shapes (no time/env dims)."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros(
            (rollout_len, num_envs) + jnp.shape(x), jnp.asarray(x).dtype
        ),
        example_item,
    )
    return RolloutState(storage=storage, t=jnp.zeros((), jnp.int32))


def rollout_add(state: RolloutState, items) -> RolloutState:
    """Append one vectorised step (leaves (num_envs, ...)) at the cursor.

    Writes past the end are dropped (JAX out-of-bounds scatter semantics),
    so a full rollout is safe until the trainer consumes and resets it.
    """
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[state.t].set(x.astype(s.dtype)), state.storage, items
    )
    return RolloutState(storage=storage, t=state.t + 1)


def rollout_ready(state: RolloutState, rollout_len: int):
    """True once the accumulator holds a complete rollout."""
    return state.t >= rollout_len


def rollout_take(state: RolloutState):
    """The full time-major trajectory (leaves (rollout_len, num_envs, ...))."""
    return state.storage


def rollout_reset(state: RolloutState) -> RolloutState:
    """Consume: rewind the cursor (storage is overwritten in place)."""
    return RolloutState(storage=state.storage, t=jnp.zeros((), jnp.int32))


# --------------------------------------------------------------------------
# Sequence replay: the third *dataset* regime of the experience protocol,
# for recurrent off-policy systems (R2D2-style rec-MADQN). Where the flat
# table stores i.i.d. per-step rows and the rollout accumulator one
# consume-and-reset trajectory, this stores fixed-length time-major
# *windows* cut from the incoming step stream: every `stride` steps (once
# `window_len` steps have accumulated) the last `window_len` rows of each
# env lane become one stored window, overwritten FIFO at capacity, and
# sampling draws whole windows uniformly. Recurrent trainers split each
# window into a burn-in prefix (unrolled with stopped gradients to warm
# the memory) and a training suffix; the window-start memory itself rides
# *inside* the stored items — recurrent systems store the executor's
# incoming carry per step in ``Transition.extras["carry_in"]`` exactly
# like rec-PPO does, so `repro.nn.recurrent.window_start_carry` reads the
# stored row 0 and the R2D2 zero start-state approximation is never
# needed.
#
# Schedule invariant (load-bearing — see docs/ARCHITECTURE.md): `size`
# advances as a pure function of the step counter `t` (`seq_expected_size`
# is the closed form), never of the *data*, so `seq_can_sample` keeps the
# update schedule data-independent and the seed-vmap runner's hoisted
# update gate (`_one_iteration_seeds`) stays sound. Prioritized *sampling*
# may key on data; prioritized fill-triggered updates must not.


class SeqBufferState(NamedTuple):
    """Sequence-replay table: windows + a rolling ring of the live stream."""

    storage: Any          # pytree, leaves (capacity, window_len, ...) — windows
    acc: Any              # pytree, leaves (window_len, num_envs, ...) — step ring
    t: jnp.ndarray        # () int32 — total steps observed
    insert_pos: jnp.ndarray  # () int32 — next window slot to overwrite
    size: jnp.ndarray     # () int32 — stored windows (pure function of t)


def seq_init(example_item, capacity: int, window_len: int, num_envs: int) -> SeqBufferState:
    """A fresh sequence buffer of ``capacity`` windows of ``window_len`` steps.

    ``example_item``: a pytree with per-item shapes (no time/env dims) —
    for recurrent systems a `Transition` whose extras carry the per-step
    ``carry_in`` row. ``num_envs`` sizes the rolling step ring; each flush
    inserts one window per env lane.
    """
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros(
            (capacity, window_len) + jnp.shape(x), jnp.asarray(x).dtype
        ),
        example_item,
    )
    acc = jax.tree_util.tree_map(
        lambda x: jnp.zeros(
            (window_len, num_envs) + jnp.shape(x), jnp.asarray(x).dtype
        ),
        example_item,
    )
    return SeqBufferState(
        storage=storage,
        acc=acc,
        t=jnp.zeros((), jnp.int32),
        insert_pos=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def seq_add(state: SeqBufferState, items, *, stride: int) -> SeqBufferState:
    """Append one vectorised step (leaves ``(num_envs, ...)``); flush windows.

    The step lands in the rolling ring; once ``window_len`` steps have
    accumulated, every ``stride``-th step flushes the ring — the last
    ``window_len`` rows of each env lane, in time order — into the window
    table, overwriting FIFO at capacity.  ``stride < window_len`` makes
    consecutive windows overlap by ``window_len - stride`` steps (the
    R2D2 idiom: stride ``seq_len`` overlaps exactly the burn-in prefix,
    so every transition trains once).  The flush condition depends only
    on the step counter, never the data (see the regime note above).
    """
    acc_leaves = jax.tree_util.tree_leaves(state.acc)
    window_len, num_envs = acc_leaves[0].shape[:2]
    capacity = jax.tree_util.tree_leaves(state.storage)[0].shape[0]

    pos = state.t % window_len
    acc = jax.tree_util.tree_map(
        lambda s, x: s.at[pos].set(x.astype(s.dtype)), state.acc, items
    )
    t1 = state.t + 1
    flush = (t1 >= window_len) & ((t1 - window_len) % stride == 0)
    # ring slots in time order: order[j] holds step (t1 - window_len + j)
    order = (pos + 1 + jnp.arange(window_len)) % window_len
    idx = (state.insert_pos + jnp.arange(num_envs)) % capacity

    def insert(s, a):
        windows = jnp.moveaxis(a[order], 0, 1)  # (num_envs, window_len, ...)
        return s.at[idx].set(jnp.where(flush, windows, s[idx]))

    storage = jax.tree_util.tree_map(insert, state.storage, acc)
    grow = jnp.where(flush, num_envs, 0).astype(jnp.int32)
    return SeqBufferState(
        storage=storage,
        acc=acc,
        t=t1,
        insert_pos=(state.insert_pos + grow) % capacity,
        size=jnp.minimum(state.size + grow, capacity),
    )


def seq_sample(state: SeqBufferState, key, batch_size: int):
    """Uniformly sample ``batch_size`` whole windows, time-major.

    Returns the stored pytree with leaves ``(window_len, batch_size, ...)``
    — the same (T, B) layout BPTT trainers consume from the rollout
    accumulator, stored ``extras["carry_in"]`` rows included.
    """
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(
        lambda s: jnp.moveaxis(s[idx], 0, 1), state.storage
    )


def seq_can_sample(state: SeqBufferState, min_windows: int):
    """True once at least ``min_windows`` windows are stored."""
    return state.size >= min_windows


def seq_expected_size(
    t: int, capacity: int, window_len: int, num_envs: int, stride: int
) -> int:
    """Closed-form ``size`` after ``t`` `seq_add` calls (host-side int math).

    The buffer's fill is a pure function of the step counter: ``t`` steps
    produce ``max(0, (t - window_len) // stride + 1)`` flushes of
    ``num_envs`` windows each, capped at ``capacity``.  Tests pin
    `SeqBufferState.size` against this to guard the data-independent
    update-schedule invariant the seed-vmap runner relies on.
    """
    flushes = max(0, (t - window_len) // stride + 1)
    return min(num_envs * flushes, capacity)


# --------------------------------------------------------------------------
# Device-resident trajectory queue: the transport structure of the
# experience protocol, used by the async actor/learner runner
# (`repro.distributed.impala`). Where the replay table and the rollout
# accumulator are *datasets* (owned by the learner), the queue is a
# *transport*: a fixed-capacity FIFO ring of trajectory-chunk slots that
# decouples actor production from learner consumption inside one fused jit.
# Items are arbitrary pytrees (a time-major Transition chunk plus update
# keys and staleness metadata); push to a full queue drops the incoming
# item (the runner counts drops), pop of an empty queue is gated by the
# caller on `queue_size`.


class QueueState(NamedTuple):
    """A fixed-capacity FIFO ring of pytree slots, fully device-resident."""

    storage: Any          # pytree, leaves (capacity, ...) — one slot per item
    head: jnp.ndarray     # () int32 — slot index of the oldest queued item
    size: jnp.ndarray     # () int32 — number of items currently queued


def queue_init(example_item, capacity: int) -> QueueState:
    """A fresh empty queue; ``example_item`` fixes slot shapes and dtypes."""
    storage = jax.tree_util.tree_map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example_item,
    )
    return QueueState(
        storage=storage,
        head=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def queue_capacity(state: QueueState) -> int:
    """The static number of slots the queue was built with."""
    return jax.tree_util.tree_leaves(state.storage)[0].shape[0]


def queue_size(state: QueueState):
    """How many items are currently queued (a traced scalar)."""
    return state.size


def queue_push(state: QueueState, item):
    """Enqueue one item at the tail; a full queue drops the *incoming* item.

    Returns ``(state, accepted)`` where ``accepted`` is a scalar bool —
    False means the item was dropped (bounded-queue backpressure; the
    async runner surfaces the drop count in its telemetry).
    """
    capacity = queue_capacity(state)
    ok = state.size < capacity
    slot = (state.head + state.size) % capacity
    storage = jax.tree_util.tree_map(
        lambda s, x: s.at[slot].set(
            jnp.where(ok, x.astype(s.dtype), s[slot])
        ),
        state.storage,
        item,
    )
    return (
        QueueState(
            storage=storage,
            head=state.head,
            size=state.size + ok.astype(jnp.int32),
        ),
        ok,
    )


def queue_pop(state: QueueState):
    """Dequeue the oldest item (FIFO).

    Returns ``(state, item)``.  Popping an empty queue returns the stale
    contents of the head slot and leaves the queue empty — callers gate on
    `queue_size` (the async runner wraps every pop in a ``lax.cond``).
    """
    capacity = queue_capacity(state)
    has = state.size > 0
    item = jax.tree_util.tree_map(lambda s: s[state.head], state.storage)
    return (
        QueueState(
            storage=state.storage,
            head=jnp.where(has, (state.head + 1) % capacity, state.head),
            size=state.size - has.astype(jnp.int32),
        ),
        item,
    )
