"""Core MARL types (executor/trainer currency)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple


class Transition(NamedTuple):
    """One multi-agent environment transition (the dataset row).

    ``extras`` is the executor's side-channel: whatever ``select_actions``
    returns as its third output is stored here verbatim (PPO's behaviour
    log-probs and values, DIAL's outgoing messages, recurrent systems'
    incoming `Carry` under the ``"carry_in"`` key, ...), so on-policy
    trainers can consume act-time quantities without recomputation.
    ``step_type`` is the StepType of the observation at t — FIRST marks
    episode starts, which recurrent trainers use to reset their cores when
    a stored trajectory crosses an auto-reset boundary.
    """

    obs: Dict[str, Any]        # per-agent observation at t
    actions: Dict[str, Any]    # per-agent action taken at t
    rewards: Dict[str, Any]    # per-agent reward from the step
    discount: Any              # shared discount (0 on terminal)
    next_obs: Dict[str, Any]   # per-agent observation at t+1
    state: Any                 # global state at t (centralised training)
    next_state: Any            # global state at t+1
    extras: Dict[str, Any] = {}
    step_type: Any = ()        # StepType at t (() = not recorded)


class Carry(NamedTuple):
    """Typed executor memory (the recurrent-core protocol's carry state).

    Recurrent systems thread one of these per env copy through
    ``select_actions`` and ``SystemState.carry``; feed-forward systems use
    the empty pytree ``()`` instead.  ``hidden`` holds the memory cores'
    state (any pytree — e.g. per-agent GRU hidden vectors, or nested
    actor/critic dicts for recurrent PPO); ``message`` holds outgoing
    inter-agent messages for communicating systems (DIAL/RIAL) and stays
    the empty pytree elsewhere.

    The runners reset a `Carry` at `AutoReset` FIRST boundaries via
    `repro.nn.recurrent.reset_carry` (every leaf restarts at zero with the
    new episode), and on-policy recurrent trainers store the incoming
    carry per step in ``Transition.extras["carry_in"]`` so BPTT windows
    re-run from the exact executor state (`window_start_carry`).
    """

    hidden: Any        # pytree of memory-core state (per agent, per env)
    message: Any = ()  # outgoing comm messages (() = non-communicating)


class EvalMetrics(NamedTuple):
    """Per-episode evaluation results (the currency of ``repro.eval``).

    Every leaf has a leading episode axis E. ``episode_return`` is the team
    return (mean over agents of the per-agent undiscounted return), matching
    the cooperative shared-reward convention used by the mixers.
    """

    episode_return: Any              # (E,) team return per episode
    agent_returns: Dict[str, Any]    # per-agent (E,) undiscounted returns
    episode_length: Any              # (E,) steps until termination


class TrainState(NamedTuple):
    """Parameters + optimizer state + bookkeeping for a trainer."""

    params: Any
    target_params: Any
    opt_state: Any
    steps: Any


class SystemState(NamedTuple):
    """Everything a running system owns (executor + trainer + dataset)."""

    train: TrainState
    buffer: Any
    env_state: Any
    timestep: Any
    carry: Any       # recurrent hidden / comm messages, per env
    key: Any
