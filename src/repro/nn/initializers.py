"""Weight initializers (functional, keyed)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def zeros(key, shape, dtype=jnp.float32):
    """All-zeros init (key ignored; matches the keyed initializer signature)."""
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    """All-ones init (key ignored; matches the keyed initializer signature)."""
    del key
    return jnp.ones(shape, dtype)


def normal(stddev: float = 1.0):
    """Gaussian init with the given standard deviation."""
    def init(key, shape, dtype=jnp.float32):
        return (jax.random.normal(key, shape) * stddev).astype(dtype)

    return init


def truncated_normal(stddev: float = 1.0):
    """Gaussian init truncated at two standard deviations."""
    def init(key, shape, dtype=jnp.float32):
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(
            dtype
        )

    return init


def lecun_normal(in_axis: int = -2):
    """Fan-in scaled truncated normal (default for matmul weights)."""

    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
        stddev = 1.0 / math.sqrt(fan_in)
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(
            dtype
        )

    return init


def orthogonal(scale: float = 1.0):
    """Orthogonal init (QR of a Gaussian), the PPO-style policy default."""
    def init(key, shape, dtype=jnp.float32):
        if len(shape) < 2:
            raise ValueError("orthogonal init needs >=2D shape")
        n_rows = shape[-2]
        n_cols = shape[-1]
        matrix_shape = (max(n_rows, n_cols), min(n_rows, n_cols))
        a = jax.random.normal(key, matrix_shape, jnp.float32)
        q, r = jnp.linalg.qr(a)
        q = q * jnp.sign(jnp.diagonal(r))
        if n_rows < n_cols:
            q = q.T
        q = jnp.broadcast_to(q, shape)
        return (scale * q).astype(dtype)

    return init
