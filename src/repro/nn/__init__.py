"""Minimal functional neural-network substrate (no flax available offline).

Every layer is a dataclass with two pure methods:

  init(key) -> params        (a nested dict pytree of jnp arrays)
  apply(params, *args)       (pure forward function)

and one metadata method:

  axes() -> pytree matching init's output whose leaves are tuples of
  *logical axis names* (or None), consumed by repro.distributed.sharding
  to produce NamedShardings.
"""
from repro.nn.layers import (
    Dense,
    Embed,
    RMSNorm,
    LayerNorm,
    MLP,
    GRUCell,
    Sequential,
)
from repro.nn.recurrent import (
    LinearScannedRNN,
    ScannedRNN,
    burn_in_carry,
    make_core,
    reset_carry,
    window_start_carry,
)
from repro.nn import initializers

__all__ = [
    "Dense",
    "Embed",
    "RMSNorm",
    "LayerNorm",
    "MLP",
    "GRUCell",
    "LinearScannedRNN",
    "ScannedRNN",
    "Sequential",
    "burn_in_carry",
    "initializers",
    "make_core",
    "reset_carry",
    "window_start_carry",
]
