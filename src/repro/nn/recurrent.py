"""The shared memory-core protocol for recurrent executors.

Every recurrent system in the library (rec-IPPO / rec-MAPPO / DIAL / RIAL)
threads its memory through the same three pieces:

* a **memory core** with the JaxMARL-style
  ``(carry, inputs) -> (carry, outputs)`` contract, stepped once at act
  time and unrolled over stored trajectories at train time, with
  episode-boundary resets applied *inside* the scan (no host round
  trips).  Two interchangeable cores implement the contract — `ScannedRNN`
  (the GRU reference path, sequential ``lax.scan`` BPTT) and
  `LinearScannedRNN` (a gated-linear / minGRU-style cell whose unroll is
  an exact associative scan, dispatched to the fused
  `repro.kernels.recurrent_scan` path) — selected per system through
  `make_core` / the systems' ``recurrent_core`` config field;
* `reset_carry` — the one reset-masking rule: zero (or re-initialise)
  executor memory wherever a step is the FIRST of a new episode.  The
  Anakin/shard_map runners apply it at `AutoReset` boundaries, and BPTT
  trainers apply it at stored FIRST rows — both call this helper;
* `window_start_carry` — the one code path deciding what memory a BPTT
  window opens with.  Every recurrent trainer in the library stores the
  executor's incoming carry per step in ``Transition.extras["carry_in"]``
  and re-runs from the stored window-start carry: exact for the on-policy
  family (rollout windows never span a parameter update) and for
  DIAL/RIAL, and the R2D2 *stored-state* start for sequence-replay
  systems (rec-MADQN), where the stored carry came from earlier params —
  the standard R2D2 trade, softened by `burn_in_carry`.  The zero
  start-state fallback remains only for callers with no stored carries
  (none in-tree; kept as the documented degenerate case);
* `burn_in_carry` — the R2D2 burn-in rule for sequence replay: unroll the
  window's burn-in prefix from the stored start carry to warm the memory
  under *current* params, then stop gradients, so TD errors only shape
  the training suffix.

The executor-side carry itself is the typed `repro.core.types.Carry`
(hidden state + optional outgoing messages), stored per env copy in
``SystemState.carry`` and reset by the runners via `reset_carry`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import Dense, GRUCell


@dataclasses.dataclass(frozen=True)
class ScannedRNN:
    """A GRU memory core with a ``(carry, inputs) -> (carry, outputs)`` contract.

    The act-time and train-time faces of one recurrent cell:

    * ``step(params, carry, x[, reset])`` — one cell application, used by
      executors (one env step at a time);
    * ``unroll(params, carry, xs[, resets])`` — ``lax.scan`` of ``step``
      over a leading time axis, used by BPTT trainers re-running a stored
      trajectory differentiably.

    Both faces apply the same reset rule before the cell fires: where
    ``reset`` is True the incoming carry is zeroed, so hidden state never
    leaks across an episode boundary (the rollout-scan analogue of
    JaxMARL's ScannedRNN reset masking).  The output at each step is the
    new hidden state.
    """

    in_dim: int
    hidden_dim: int

    @property
    def cell(self) -> GRUCell:
        """The underlying GRU cell (dataclass layers are free to build)."""
        return GRUCell(self.in_dim, self.hidden_dim)

    def init(self, key):
        """Initialise the cell parameters."""
        return self.cell.init(key)

    def initial_carry(self, batch_shape=()):
        """The zero hidden state, shaped ``(*batch_shape, hidden_dim)``."""
        return jnp.zeros((*batch_shape, self.hidden_dim))

    def step(self, params, carry, x, reset=None):
        """One cell application: ``(carry, x) -> (new_carry, output)``.

        ``reset`` (optional, shape ``carry.shape[:-1]``) zeroes the
        incoming carry where True before the cell fires — pass the
        FIRST-step mask when stepping across episode boundaries; omit it
        when the caller guarantees fresh carries (the runners reset
        `SystemState.carry` themselves via `reset_carry`).
        """
        if reset is not None:
            carry = jnp.where(reset[..., None], jnp.zeros_like(carry), carry)
        h = self.cell.apply(params, carry, x)
        return h, h

    def unroll(self, params, carry, xs, resets=None):
        """Scan ``step`` over a leading time axis.

        ``xs``: ``(T, ..., in_dim)`` inputs; ``resets``: ``(T, ...)``
        booleans marking rows that start a new episode (zero the carry
        before that row's cell).  Returns ``(final_carry, outputs)`` with
        outputs stacked ``(T, ..., hidden_dim)``.
        """
        if resets is None:
            resets = jnp.zeros(xs.shape[:-1], bool)

        def body(h, inp):
            x, r = inp
            return self.step(params, h, x, r)

        return jax.lax.scan(body, carry, (xs, resets))

    def axes(self):
        """Logical sharding axes (delegates to the GRU cell)."""
        return self.cell.axes()


@dataclasses.dataclass(frozen=True)
class LinearScannedRNN:
    """A gated-linear memory core whose unroll is an exact associative scan.

    The minGRU-style update (Feng et al. 2024's "were RNNs all we
    needed?" simplification):

        z_t    = sigmoid(x_t W_z + c_z)          (update gate)
        cand_t = tanh(x_t W_h + c_h)             (candidate state)
        h_t    = (1 - z_t) * h_{t-1} + z_t * cand_t

    Unlike the GRU, both gates depend on the *input only* — there is no
    ``h_{t-1}``-dependent nonlinearity — so the recurrence is linear in
    the hidden state: ``h_t = a_t * h_{t-1} + b_t`` with
    ``a = 1 - z, b = z * cand``.  First-order linear recurrences compose
    associatively, which is exactly what makes the whole-trajectory unroll
    a single fused `repro.kernels.recurrent_scan` call (log-depth
    parallel scan; blocked Pallas kernel on TPU) instead of a sequential
    per-step ``lax.scan``.  That is this core's reason to exist: same
    ``(carry, inputs) -> (carry, outputs)`` contract as `ScannedRNN`,
    drop-in behind any system's ``recurrent_core="linear"`` config, but
    the BPTT hot path parallelises over time.

    Episode-boundary resets fold into the decay coefficient inside the
    fused scan (``a_t <- a_t * (1 - reset_t)``) — the kernel-side form of
    the `reset_carry` rule; `step` applies the identical masking rule at
    act time, so executor and trainer see one semantics.

    Parameters are one fused input projection ``(in_dim, 2 * hidden_dim)``
    (a `Dense`), split into the gate and candidate halves.
    """

    in_dim: int
    hidden_dim: int

    @property
    def proj(self) -> Dense:
        """The fused gate+candidate input projection layer."""
        return Dense(self.in_dim, 2 * self.hidden_dim)

    def init(self, key):
        """Initialise the projection parameters."""
        return {"proj": self.proj.init(key)}

    def initial_carry(self, batch_shape=()):
        """The zero hidden state, shaped ``(*batch_shape, hidden_dim)``."""
        return jnp.zeros((*batch_shape, self.hidden_dim))

    def _gates(self, params, x):
        """Decay and forcing coefficients ``(a, b)`` for inputs ``x``."""
        g = self.proj.apply(params["proj"], x)
        z = jax.nn.sigmoid(g[..., : self.hidden_dim])
        cand = jnp.tanh(g[..., self.hidden_dim :])
        return 1.0 - z, z * cand

    def step(self, params, carry, x, reset=None):
        """One cell application: ``(carry, x) -> (new_carry, output)``.

        Same signature and reset semantics as `ScannedRNN.step`; the
        output at each step is the new hidden state.
        """
        a, b = self._gates(params, x)
        if reset is not None:
            a = a * (1.0 - reset[..., None].astype(a.dtype))
        h = a * carry + b
        return h, h

    def unroll(self, params, carry, xs, resets=None):
        """Fused whole-trajectory unroll (the associative-scan hot path).

        Same contract as `ScannedRNN.unroll` — ``xs``: ``(T, ..., in_dim)``,
        ``resets``: ``(T, ...)`` booleans, returns ``(final_carry,
        outputs)`` — but instead of scanning `step` sequentially it
        computes all gates in one batched projection and hands the
        resulting linear recurrence to `repro.kernels.recurrent_scan`
        (reset masking included, inside the kernel).
        """
        from repro.kernels.recurrent_scan import linear_recurrent_scan

        a, b = self._gates(params, xs)
        hs = linear_recurrent_scan(a, b, carry, resets)
        return hs[-1], hs

    def axes(self):
        """Logical sharding axes (delegates to the projection layer)."""
        return {"proj": self.proj.axes()}


# The registry of memory cores selectable via the systems'
# ``recurrent_core`` config field ("gru" is the reference path every seed
# milestone is pinned on; "linear" is the fused associative-scan path).
CORES = {"gru": ScannedRNN, "linear": LinearScannedRNN}


def make_core(kind: str, in_dim: int, hidden_dim: int):
    """Build a memory core by registry name (``"gru"`` or ``"linear"``)."""
    try:
        cls = CORES[kind]
    except KeyError:
        raise ValueError(
            f"unknown recurrent core {kind!r}; choose from {sorted(CORES)}"
        ) from None
    return cls(in_dim, hidden_dim)


def reset_carry(carry, reset, initial=None):
    """Reset executor memory where ``reset`` is True (the one masking rule).

    ``carry`` is any pytree of arrays whose leading dims match ``reset``'s
    shape (per-env hidden states, outgoing messages, ...); ``reset`` is
    broadcast over each leaf's trailing dims.  ``initial`` supplies the
    fresh value (defaults to zeros, which every memory core in the library
    uses as its start state).

    Call sites: the runners' rollout scan (zero `SystemState.carry` at
    `AutoReset` FIRST boundaries) and BPTT trainers (zero the replayed
    carry at stored FIRST rows).
    """
    if initial is None:
        initial = jax.tree_util.tree_map(jnp.zeros_like, carry)

    def sel(fresh, old):
        r = reset.reshape(reset.shape + (1,) * (old.ndim - reset.ndim))
        return jnp.where(r, fresh, old)

    return jax.tree_util.tree_map(sel, initial, carry)


def window_start_carry(extras, initial_carry, batch_shape):
    """The memory a BPTT window opens with — the stored carry row 0.

    Every recurrent trainer records the executor's incoming carry per step
    in ``extras["carry_in"]``; the window-start carry is the stored row 0.
    For the rollout regime (rec-IPPO / rec-MAPPO / DIAL / RIAL) this is
    *exact*: the accumulator consumes-and-resets on every update, so the
    stored carries were produced by the parameters being trained.  For the
    sequence-replay regime (rec-MADQN) it is the R2D2 *stored-state*
    start: the carry came from the acting-time (possibly older) params —
    strictly closer to the truth than restarting from zeros, and the
    residual mismatch is what `burn_in_carry`'s warm-up absorbs.

    Callers with no stored carries fall back to
    ``initial_carry(batch_shape)`` — the R2D2 zero start-state
    approximation.  No in-tree trainer uses this path any more (DIAL
    retired it when its executor started storing carries); it stays as the
    documented degenerate case for extras-less callers.
    """
    if "carry_in" in extras:
        return jax.tree_util.tree_map(lambda x: x[0], extras["carry_in"])
    return initial_carry(batch_shape)


def burn_in_carry(unroll, carry, xs, resets):
    """Warm a sequence-replay window's start memory over its burn-in prefix.

    The R2D2 burn-in rule: unroll the memory core over the window's first
    ``burn_in`` rows starting from the stored window-start carry (see
    `window_start_carry`), then **stop gradients** on the resulting carry
    — the prefix exists to refresh stale memory under current parameters,
    not to receive TD gradients; training only shapes the suffix.

    ``unroll`` is the caller's core closure with the standard
    ``(carry, xs, resets) -> (carry, outputs)`` contract (e.g. one agent's
    encoder->core stack); ``xs`` / ``resets`` are the burn-in prefix rows,
    time-major.  A zero-length prefix (``burn_in = 0``) skips the unroll
    and returns the (stop-gradiented) stored carry directly.
    """
    if jax.tree_util.tree_leaves(xs)[0].shape[0] == 0:
        return jax.lax.stop_gradient(carry)
    carry, _ = unroll(carry, xs, resets)
    return jax.lax.stop_gradient(carry)
