"""The shared memory-core protocol for recurrent executors.

Every recurrent system in the library (rec-IPPO / rec-MAPPO / DIAL / RIAL)
threads its memory through the same three pieces:

* `ScannedRNN` — a GRU core with the JaxMARL-style
  ``(carry, inputs) -> (carry, outputs)`` contract, stepped once at act
  time and `lax.scan`-unrolled over stored trajectories at train time,
  with episode-boundary resets applied *inside* the scan (no host round
  trips);
* `reset_carry` — the one reset-masking rule: zero (or re-initialise)
  executor memory wherever a step is the FIRST of a new episode.  The
  Anakin/shard_map runners apply it at `AutoReset` boundaries, and BPTT
  trainers apply it at stored FIRST rows — both call this helper;
* `window_start_carry` — the one code path deciding what memory a BPTT
  window opens with.  On-policy recurrent trainers store the executor's
  incoming carry per step in ``Transition.extras["carry_in"]`` and re-run
  from the stored window-start carry (exact: on-policy windows never span
  a parameter update).  Trainers that do not store carries (DIAL/RIAL)
  fall back to the R2D2 *zero start-state approximation* — a window that
  opens mid-episode replays from zeroed memory, accepting a small state
  mismatch.  This fallback line is the approximation's single home; it
  matters only when ``rollout_len`` is shorter than the episode.

The executor-side carry itself is the typed `repro.core.types.Carry`
(hidden state + optional outgoing messages), stored per env copy in
``SystemState.carry`` and reset by the runners via `reset_carry`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import GRUCell


@dataclasses.dataclass(frozen=True)
class ScannedRNN:
    """A GRU memory core with a ``(carry, inputs) -> (carry, outputs)`` contract.

    The act-time and train-time faces of one recurrent cell:

    * ``step(params, carry, x[, reset])`` — one cell application, used by
      executors (one env step at a time);
    * ``unroll(params, carry, xs[, resets])`` — ``lax.scan`` of ``step``
      over a leading time axis, used by BPTT trainers re-running a stored
      trajectory differentiably.

    Both faces apply the same reset rule before the cell fires: where
    ``reset`` is True the incoming carry is zeroed, so hidden state never
    leaks across an episode boundary (the rollout-scan analogue of
    JaxMARL's ScannedRNN reset masking).  The output at each step is the
    new hidden state.
    """

    in_dim: int
    hidden_dim: int

    @property
    def cell(self) -> GRUCell:
        """The underlying GRU cell (dataclass layers are free to build)."""
        return GRUCell(self.in_dim, self.hidden_dim)

    def init(self, key):
        """Initialise the cell parameters."""
        return self.cell.init(key)

    def initial_carry(self, batch_shape=()):
        """The zero hidden state, shaped ``(*batch_shape, hidden_dim)``."""
        return jnp.zeros((*batch_shape, self.hidden_dim))

    def step(self, params, carry, x, reset=None):
        """One cell application: ``(carry, x) -> (new_carry, output)``.

        ``reset`` (optional, shape ``carry.shape[:-1]``) zeroes the
        incoming carry where True before the cell fires — pass the
        FIRST-step mask when stepping across episode boundaries; omit it
        when the caller guarantees fresh carries (the runners reset
        `SystemState.carry` themselves via `reset_carry`).
        """
        if reset is not None:
            carry = jnp.where(reset[..., None], jnp.zeros_like(carry), carry)
        h = self.cell.apply(params, carry, x)
        return h, h

    def unroll(self, params, carry, xs, resets=None):
        """Scan ``step`` over a leading time axis.

        ``xs``: ``(T, ..., in_dim)`` inputs; ``resets``: ``(T, ...)``
        booleans marking rows that start a new episode (zero the carry
        before that row's cell).  Returns ``(final_carry, outputs)`` with
        outputs stacked ``(T, ..., hidden_dim)``.
        """
        if resets is None:
            resets = jnp.zeros(xs.shape[:-1], bool)

        def body(h, inp):
            x, r = inp
            return self.step(params, h, x, r)

        return jax.lax.scan(body, carry, (xs, resets))

    def axes(self):
        """Logical sharding axes (delegates to the GRU cell)."""
        return self.cell.axes()


def reset_carry(carry, reset, initial=None):
    """Reset executor memory where ``reset`` is True (the one masking rule).

    ``carry`` is any pytree of arrays whose leading dims match ``reset``'s
    shape (per-env hidden states, outgoing messages, ...); ``reset`` is
    broadcast over each leaf's trailing dims.  ``initial`` supplies the
    fresh value (defaults to zeros, which every memory core in the library
    uses as its start state).

    Call sites: the runners' rollout scan (zero `SystemState.carry` at
    `AutoReset` FIRST boundaries) and BPTT trainers (zero the replayed
    carry at stored FIRST rows).
    """
    if initial is None:
        initial = jax.tree_util.tree_map(jnp.zeros_like, carry)

    def sel(fresh, old):
        r = reset.reshape(reset.shape + (1,) * (old.ndim - reset.ndim))
        return jnp.where(r, fresh, old)

    return jax.tree_util.tree_map(sel, initial, carry)


def window_start_carry(extras, initial_carry, batch_shape):
    """The memory a BPTT window opens with — stored carry, else zeros.

    On-policy recurrent trainers (rec-IPPO / rec-MAPPO) record the
    executor's incoming carry per step in ``extras["carry_in"]``; the
    window-start carry is then the stored row 0, which is *exact*: the
    rollout accumulator consumes-and-resets on every update, so the stored
    carries were produced by the parameters being trained.

    Trainers that do not store carries fall back to
    ``initial_carry(batch_shape)`` — the R2D2 zero start-state
    approximation, kept to this single code path: a window that opens
    mid-episode replays from zeroed memory rather than the executor's true
    state.  Exact only when windows are episode-aligned (DIAL's default
    ``rollout_len = env.horizon``); see ROADMAP for the episode-aligned
    alternative if mid-episode windows regress at scale.
    """
    if "carry_in" in extras:
        return jax.tree_util.tree_map(lambda x: x[0], extras["carry_in"])
    return initial_carry(batch_shape)
