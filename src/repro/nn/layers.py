"""Core layers for the MARL networks (MLPs, GRUs, Q-nets).

The large-model layers (attention, MoE, SSM) live in repro.models and are
written as explicit init/apply function pairs for full control over sharding
and remat; these dataclass layers are the convenience substrate used by the
MARL systems, which run at laptop scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.nn import initializers


@dataclasses.dataclass(frozen=True)
class Dense:
    """Affine layer ``y = x @ w (+ b)`` with configurable init and axes."""

    in_dim: int
    out_dim: int
    use_bias: bool = True
    w_init: Callable = dataclasses.field(default_factory=initializers.lecun_normal)
    dtype: jnp.dtype = jnp.float32
    logical_axes: tuple = (None, None)

    def init(self, key):
        """Initialise ``{"w", ("b")}`` with `w_init` / zeros."""
        wkey, _ = jax.random.split(key)
        params = {"w": self.w_init(wkey, (self.in_dim, self.out_dim), self.dtype)}
        if self.use_bias:
            params["b"] = jnp.zeros((self.out_dim,), self.dtype)
        return params

    def apply(self, params, x):
        """Apply the affine map to the trailing dim of ``x``."""
        y = x @ params["w"]
        if self.use_bias:
            y = y + params["b"]
        return y

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        out = {"w": self.logical_axes}
        if self.use_bias:
            out["b"] = (self.logical_axes[1],)
        return out


@dataclasses.dataclass(frozen=True)
class Embed:
    """Token-embedding table lookup (with tied-output `attend`)."""

    vocab: int
    dim: int
    dtype: jnp.dtype = jnp.float32
    logical_axes: tuple = (None, None)

    def init(self, key):
        """Initialise the ``(vocab, dim)`` embedding table."""
        return {"embedding": initializers.normal(1.0)(key, (self.vocab, self.dim), self.dtype)}

    def apply(self, params, ids):
        """Look up rows of the table for integer ``ids``."""
        return jnp.take(params["embedding"], ids, axis=0)

    def attend(self, params, x):
        """Tied-output logits."""
        return x @ params["embedding"].T

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        return {"embedding": self.logical_axes}


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    """Root-mean-square normalisation (no mean subtraction, fp32 math)."""

    dim: int
    eps: float = 1e-6

    def init(self, key):
        """Initialise the per-feature ``scale`` at ones."""
        del key
        return {"scale": jnp.ones((self.dim,), jnp.float32)}

    def apply(self, params, x):
        """Normalise the trailing dim by its RMS and rescale."""
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"]).astype(x.dtype)

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        return {"scale": (None,)}


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    """Standard layer normalisation (mean/variance over the trailing dim)."""

    dim: int
    eps: float = 1e-5

    def init(self, key):
        """Initialise ``scale`` at ones and ``bias`` at zeros."""
        del key
        return {
            "scale": jnp.ones((self.dim,), jnp.float32),
            "bias": jnp.zeros((self.dim,), jnp.float32),
        }

    def apply(self, params, x):
        """Normalise the trailing dim, then rescale and shift."""
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"] + params["bias"]).astype(x.dtype)

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        return {"scale": (None,), "bias": (None,)}


@dataclasses.dataclass(frozen=True)
class MLP:
    """Plain multi-layer perceptron used by MARL policy/critic networks."""

    sizes: Sequence[int]  # [in, hidden..., out]
    activation: Callable = jax.nn.relu
    activate_final: bool = False
    w_init: Callable = dataclasses.field(default_factory=initializers.orthogonal)

    def _layers(self):
        return [
            Dense(self.sizes[i], self.sizes[i + 1], w_init=self.w_init)
            for i in range(len(self.sizes) - 1)
        ]

    def init(self, key):
        """Initialise one ``dense_{i}`` sub-tree per layer."""
        layers = self._layers()
        keys = jax.random.split(key, len(layers))
        return {f"dense_{i}": l.init(k) for i, (l, k) in enumerate(zip(layers, keys))}

    def apply(self, params, x):
        """Forward pass, activating between layers (and after, if asked)."""
        layers = self._layers()
        for i, layer in enumerate(layers):
            x = layer.apply(params[f"dense_{i}"], x)
            if i < len(layers) - 1 or self.activate_final:
                x = self.activation(x)
        return x

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        return {f"dense_{i}": l.axes() for i, l in enumerate(self._layers())}


@dataclasses.dataclass(frozen=True)
class GRUCell:
    """Minimal GRU cell for recurrent executors (R2D2-style MADQN / DIAL)."""

    in_dim: int
    hidden_dim: int

    def init(self, key):
        """Initialise input/hidden gate projections and their biases."""
        k1, k2, k3 = jax.random.split(key, 3)
        h = self.hidden_dim
        lecun = initializers.lecun_normal()
        return {
            "wi": lecun(k1, (self.in_dim, 3 * h)),
            "wh": initializers.orthogonal()(k2, (h, 3 * h)),
            "bi": jnp.zeros((3 * h,)),
            "bh": jnp.zeros((3 * h,)),
        }

    def apply(self, params, h, x):
        """h: (..., hidden), x: (..., in) -> new h."""
        gates_x = x @ params["wi"] + params["bi"]
        gates_h = h @ params["wh"] + params["bh"]
        xr, xz, xn = jnp.split(gates_x, 3, axis=-1)
        hr, hz, hn = jnp.split(gates_h, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        return (1.0 - z) * n + z * h

    def initial_state(self, batch_shape=()):
        """The zero hidden state, shaped ``(*batch_shape, hidden_dim)``."""
        return jnp.zeros((*batch_shape, self.hidden_dim))

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        return {"wi": (None, None), "wh": (None, None), "bi": (None,), "bh": (None,)}


@dataclasses.dataclass(frozen=True)
class Sequential:
    """Compose layers in order, each reading its own ``layer_{i}`` params."""

    layers: Sequence

    def init(self, key):
        """Initialise one ``layer_{i}`` sub-tree per layer."""
        keys = jax.random.split(key, len(self.layers))
        return {f"layer_{i}": l.init(k) for i, (l, k) in enumerate(zip(self.layers, keys))}

    def apply(self, params, x):
        """Apply each layer in sequence."""
        for i, layer in enumerate(self.layers):
            x = layer.apply(params[f"layer_{i}"], x)
        return x

    def axes(self):
        """Logical sharding axes matching `init`'s pytree."""
        return {f"layer_{i}": l.axes() for i, l in enumerate(self.layers)}
