"""Training-throughput measurement across the three runner rungs.

Each (system, env) cell reports environment steps per second for

  * ``python_loop`` — the paper's Block-1 Acme-style loop (jitted fns,
    python-paced control flow; warmed first, so the number is steady-state
    dispatch overhead rather than first-call compilation);
  * ``anakin``      — the fused scan(iterations) x vmap(envs) jit, timed on
    the second call of one reusable compiled program;
  * ``shard_map``   — the same program shard_mapped over the mesh data axis
    (every local device runs its own envs + buffer shard);

plus the PR's headline column: ``seed_vectorization`` — N independent seeds
trained serially (one compiled per-seed program called N times) vs the same
N seeds as a single vmapped jit program (`train_anakin(..., num_seeds=N)`),
with identical per-seed keys so both sides do bitwise-identical work.

Recurrent cells additionally report a ``fused_recurrent`` rung: the same
anakin program with the system's memory core switched from the reference
GRU `ScannedRNN` to the fused associative-scan `LinearScannedRNN`
(``recurrent_core="linear"``), quantifying how much of the rec/ff
throughput gap the fused core closes (see docs/KERNELS.md).

Every cell also reports an ``async_actors`` rung: the IMPALA-style async
actor/learner runner (`repro.distributed.impala.make_async`) at 1/2/4
vmapped actor replicas, measuring how steps/sec scales with actor count
when rollout collection is decoupled from the learner through the
device-resident trajectory queue (see docs/DISTRIBUTED.md).

All fused timings exclude compilation (warm call first); steps/sec counts
*environment* steps summed over envs, devices and seeds.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.system import (
    make_anakin,
    make_distributed,
    run_environment_loop,
)
from repro.distributed.impala import make_async
from repro.launch.mesh import make_auto_mesh
from repro.obs import ConsoleSink, provenance
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.onpolicy import PPOConfig
from repro.systems.registry import REGISTRY, compatibility, make_pair

# the bench harness's human-facing reporting path (see repro.obs.sinks)
_console = ConsoleSink()

# The CPU smoke operating point: small enough that per-op overhead (the
# thing vmap-over-seeds amortises) is visible next to real compute, and the
# whole default slice benches in ~a minute.  Keyed by config class so every
# member of a family gets the same treatment; recorded per cell in the
# artifact so rows are comparable across PRs.  Pass explicit overrides (or
# ``{}``) to bench registry-default configs instead.
SMOKE_OVERRIDES = {
    OffPolicyConfig: dict(hidden_sizes=(32, 32), batch_size=32, buffer_capacity=5_000),
    PPOConfig: dict(hidden_sizes=(32, 32), rollout_len=32, epochs=1, num_minibatches=2),
}

_REPEATS = 3  # timed repetitions; best-of is reported (noise floor, not mean)


def smoke_overrides(system_name: str) -> dict:
    """The smoke-scale config overrides for a registered system (may be {})."""
    return dict(SMOKE_OVERRIDES.get(REGISTRY[system_name].config_cls, {}))


def _timed_warm(program, *args, repeats: int = _REPEATS) -> float:
    """Best-of-``repeats`` seconds for jit-cached calls of ``program``.

    The first (compiling) call is discarded; best-of suppresses scheduler
    noise, which on small CPU boxes easily exceeds the effects we measure.
    """
    jax.block_until_ready(program(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(program(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_python_loop(system, num_episodes: int = 3, seed: int = 0) -> Dict:
    """Steps/sec of the faithful Block-1 python loop.

    A one-episode warm-up call populates the jit caches first, so the timed
    number reflects the loop's steady state — python-paced dispatch of the
    jitted pieces — not first-call compilation.
    """
    run_environment_loop(system, jax.random.key(seed), num_episodes=1)
    t0 = time.perf_counter()
    _, _, ev = run_environment_loop(
        system, jax.random.key(seed), num_episodes=num_episodes
    )
    dt = time.perf_counter() - t0
    steps = int(np.sum(np.asarray(ev.episode_length)))
    return {"steps_per_sec": steps / dt, "env_steps": steps, "wall_seconds": dt}


def measure_anakin(system, iterations: int, num_envs: int, seed: int = 0) -> Dict:
    """Steps/sec of the fused Anakin jit (steady state, compile excluded)."""
    program = make_anakin(system, iterations, num_envs)
    dt = _timed_warm(program, jax.random.key(seed))
    steps = iterations * num_envs
    return {"steps_per_sec": steps / dt, "env_steps": steps, "wall_seconds": dt}


def measure_shard_map(
    system, iterations: int, num_envs_per_device: int, mesh=None, seed: int = 0
) -> Dict:
    """Steps/sec of the shard_map runner over every local device.

    ``system`` should be built with ``distributed_axis="data"`` so gradients
    pmean over the mesh (a no-op at one device, required beyond it).
    """
    if mesh is None:
        mesh = make_auto_mesh((jax.local_device_count(),), ("data",))
    n_dev = mesh.shape["data"]
    program = make_distributed(system, iterations, num_envs_per_device, mesh)
    dt = _timed_warm(program, jax.random.key(seed))
    steps = iterations * num_envs_per_device * n_dev
    return {
        "steps_per_sec": steps / dt,
        "env_steps": steps,
        "wall_seconds": dt,
        "num_devices": int(n_dev),
    }


def measure_seed_vectorization(
    system, num_seeds: int, iterations: int, num_envs: int
) -> Dict:
    """Serial-vs-vmapped multi-seed training speedup (the headline column).

    Both sides run the same per-seed keys (``jax.random.key(0..N-1)``) for
    the same iteration budget; the serial side reuses one compiled per-seed
    program (compile excluded from both timings), so the ratio isolates the
    vmap-over-seeds fusion win rather than retracing overhead.
    """
    keys = [jax.random.key(s) for s in range(num_seeds)]
    serial_program = make_anakin(system, iterations, num_envs)

    def _serial_sweep(ks):
        for k in ks:
            jax.block_until_ready(serial_program(k))
        return ()

    serial_dt = _timed_warm(_serial_sweep, keys)
    vmapped_program = make_anakin(
        system, iterations, num_envs, num_seeds=num_seeds
    )
    vmapped_dt = _timed_warm(vmapped_program, jnp.stack(keys))

    steps = num_seeds * iterations * num_envs
    return {
        "num_seeds": num_seeds,
        "serial_steps_per_sec": steps / serial_dt,
        "vmapped_steps_per_sec": steps / vmapped_dt,
        "speedup": serial_dt / vmapped_dt,
    }


def measure_fused_recurrent(
    system_name: str,
    env_name: str,
    iterations: int,
    num_envs: int,
    reference_steps_per_sec: float,
    overrides: dict,
) -> Dict:
    """Fused linear-core anakin throughput vs the GRU reference core.

    Rebuilds the same (system, env) cell at the same operating point with
    ``recurrent_core="linear"`` and times the same anakin program, so the
    ratio isolates the memory-core swap (gates precomputed in one batched
    projection + whole-window associative scan vs a sequential per-step
    GRU scan).  ``reference_steps_per_sec`` is the cell's already-measured
    default-core anakin number — the two rows share every other knob.
    """
    _, fused_system = make_pair(
        system_name, env_name, **{**overrides, "recurrent_core": "linear"}
    )
    fused = measure_anakin(fused_system, iterations, num_envs)
    return {
        "core": "linear",
        "reference_core": "gru",
        "reference_steps_per_sec": reference_steps_per_sec,
        "fused_steps_per_sec": fused["steps_per_sec"],
        "speedup": fused["steps_per_sec"] / reference_steps_per_sec,
    }


def measure_async_actors(
    system_name: str,
    env_name: str,
    iterations: int,
    num_envs: int,
    overrides: dict,
    actor_counts: Sequence[int] = (1, 2, 4),
    param_sync_every: int = 1,
) -> Dict:
    """Async actor/learner throughput scaling with actor count.

    One row per actor count: the same (system, env) cell trained by
    `repro.distributed.impala.make_async` with N vmapped actor replicas
    feeding the shared trajectory queue.  ``iterations`` counts env steps
    per env *per actor* (the anakin iteration unit), so total env steps —
    and the work available to amortise per-op overhead — grow with N;
    steps/sec increasing down the rows is the IMPALA scaling claim at
    single-host size.  On-policy systems run with ``use_vtrace=True``
    (the correction the async runner needs whenever staleness > 0), so
    the rung measures the production configuration.
    """
    entry = REGISTRY[system_name]
    has_vtrace = "use_vtrace" in {
        f.name for f in dataclasses.fields(entry.config_cls)
    }
    ov = {**overrides, "use_vtrace": True} if has_vtrace else dict(overrides)
    _, system = make_pair(system_name, env_name, **ov)
    rows = []
    unroll = None
    for num_actors in actor_counts:
        program = make_async(
            system, iterations, num_envs, num_actors,
            param_sync_every=param_sync_every,
        )
        unroll = program.unroll_len
        dt = _timed_warm(program, jax.random.key(0))
        steps = iterations * num_envs * num_actors
        rows.append({
            "num_actors": int(num_actors),
            "steps_per_sec": steps / dt,
            "env_steps": steps,
            "wall_seconds": dt,
        })
    return {
        "actor_counts": [int(a) for a in actor_counts],
        "param_sync_every": int(param_sync_every),
        "unroll_len": int(unroll),
        "use_vtrace": has_vtrace,
        "cells": rows,
    }


def bench_cell(
    system_name: str,
    env_name: str,
    iterations: int,
    num_envs: int,
    num_seeds: int,
    loop_episodes: int,
    system_overrides: Optional[dict] = None,
) -> Dict:
    """One BENCH_speed cell: every runner rung + the seed-vectorization row."""
    reason = compatibility(system_name, env_name)
    if reason is not None:
        return {
            "system": system_name,
            "env": env_name,
            "compatible": False,
            "reason": reason,
        }
    overrides = (
        smoke_overrides(system_name) if system_overrides is None
        else dict(system_overrides)
    )
    env, system = make_pair(system_name, env_name, **overrides)
    _, dist_system = make_pair(
        system_name, env_name, distributed_axis="data", **overrides
    )
    loop = measure_python_loop(system, loop_episodes)
    anakin = measure_anakin(system, iterations, num_envs)
    sharded = measure_shard_map(dist_system, iterations, num_envs)
    anakin["speedup_vs_loop"] = anakin["steps_per_sec"] / loop["steps_per_sec"]
    sharded["speedup_vs_loop"] = sharded["steps_per_sec"] / loop["steps_per_sec"]
    # the fused-recurrent rung applies where the system (a) exposes the
    # memory-core selector and (b) actually threads memory (ff systems
    # share PPOConfig but carry an empty pytree)
    entry = REGISTRY[system_name]
    has_core_field = "recurrent_core" in {
        f.name for f in dataclasses.fields(entry.config_cls)
    }
    is_recurrent = bool(jax.tree_util.tree_leaves(system.initial_carry(())))
    fused = (
        measure_fused_recurrent(
            system_name, env_name, iterations, num_envs,
            anakin["steps_per_sec"], overrides,
        )
        if has_core_field and is_recurrent
        else None
    )
    return {
        "system": system_name,
        "env": env_name,
        "compatible": True,
        "horizon": int(env.horizon),
        "config_overrides": {k: list(v) if isinstance(v, tuple) else v
                             for k, v in overrides.items()},
        "runners": {
            "python_loop": loop,
            "anakin": anakin,
            "shard_map": sharded,
        },
        "seed_vectorization": measure_seed_vectorization(
            system, num_seeds, iterations, num_envs
        ),
        **({"fused_recurrent": fused} if fused is not None else {}),
        "async_actors": measure_async_actors(
            system_name, env_name, iterations, num_envs, overrides
        ),
    }


def run_bench(
    system_names: Sequence[str],
    env_names: Sequence[str],
    iterations: int = 256,
    num_envs: int = 4,
    num_seeds: int = 8,
    loop_episodes: int = 3,
    out_path: str = "BENCH_speed.json",
    system_overrides: Optional[dict] = None,
) -> Dict:
    """Sweep systems x envs for throughput; write BENCH_speed.json + .md.

    Systems run at the `SMOKE_OVERRIDES` operating point unless
    ``system_overrides`` maps their name to an explicit config dict.  The
    schema (documented in docs/BENCH.md) is validated in CI by
    ``scripts/check_bench_schema.py``; append rows here for future speed
    PRs instead of inventing ad-hoc metrics.
    """
    import json

    overrides = system_overrides or {}
    results: Dict = {
        "provenance": provenance(),
        "config": {
            "iterations": iterations,
            "num_envs": num_envs,
            "num_seeds": num_seeds,
            "loop_episodes": loop_episodes,
            "backend": jax.default_backend(),
            "num_devices": jax.local_device_count(),
        },
        "cells": [],
    }
    for sys_name in system_names:
        for env_name in env_names:
            t0 = time.perf_counter()
            cell = bench_cell(
                sys_name, env_name, iterations, num_envs, num_seeds,
                loop_episodes, system_overrides=overrides.get(sys_name),
            )
            results["cells"].append(cell)
            if not cell["compatible"]:
                _console.line(f"{sys_name:>10s} x {env_name:<18s}: skipped ({cell['reason']})")
                continue
            sv = cell["seed_vectorization"]
            fr = cell.get("fused_recurrent")
            fused_note = f"fused core={fr['speedup']:.1f}x  " if fr else ""
            aa = cell["async_actors"]
            async_note = "async " + "/".join(
                f"{row['steps_per_sec']:,.0f}" for row in aa["cells"]
            ) + f" @ {aa['actor_counts']} actors  "
            _console.line(
                f"{sys_name:>10s} x {env_name:<18s}: "
                f"loop={cell['runners']['python_loop']['steps_per_sec']:,.0f} "
                f"anakin={cell['runners']['anakin']['steps_per_sec']:,.0f} "
                f"shard_map={cell['runners']['shard_map']['steps_per_sec']:,.0f} steps/s  "
                f"{sv['num_seeds']}-seed vmap speedup={sv['speedup']:.1f}x  "
                f"{fused_note}"
                f"{async_note}"
                f"({time.perf_counter() - t0:.1f}s)"
            )

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    md_path = str(pathlib.Path(out_path).with_suffix(".md"))
    with open(md_path, "w") as f:
        f.write(to_markdown(results))
    _console.line(f"wrote {out_path} and {md_path}")
    return results


def to_markdown(results: Dict) -> str:
    """Render the throughput sweep as one row per runnable cell."""
    cfg = results["config"]
    lines = [
        "# Training throughput — runners x seed vectorization",
        "",
        f"{cfg['iterations']} iterations x {cfg['num_envs']} envs per run, "
        f"{cfg['num_seeds']} seeds, backend={cfg['backend']} "
        f"({cfg['num_devices']} device(s)). Steps/sec counts environment "
        "steps over all envs/devices/seeds; `vmap speedup` is serial "
        "per-seed training vs one vmapped multi-seed jit; `fused core` is "
        "anakin with the linear associative-scan memory core vs the "
        "reference GRU (recurrent systems only, see docs/KERNELS.md); "
        "`async actors` is the IMPALA-style async actor/learner runner's "
        "steps/sec at 1/2/4 actor replicas (see docs/DISTRIBUTED.md).",
        "",
        "| system | env | python loop (steps/s) | anakin (steps/s) | "
        "shard_map (steps/s) | vmap speedup | fused core | async actors |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cell in results["cells"]:
        if not cell.get("compatible"):
            lines.append(
                f"| {cell['system']} | {cell['env']} | -- | -- | -- | -- | -- "
                "| -- |"
            )
            continue
        r, sv = cell["runners"], cell["seed_vectorization"]
        fr = cell.get("fused_recurrent")
        fused_col = (
            f"{fr['fused_steps_per_sec']:,.0f} ({fr['speedup']:.1f}x)"
            if fr else "--"
        )
        aa = cell.get("async_actors")
        async_col = (
            " / ".join(f"{row['steps_per_sec']:,.0f}" for row in aa["cells"])
            + f" @ {'/'.join(str(a) for a in aa['actor_counts'])}"
            if aa else "--"
        )
        lines.append(
            f"| {cell['system']} | {cell['env']} "
            f"| {r['python_loop']['steps_per_sec']:,.0f} "
            f"| {r['anakin']['steps_per_sec']:,.0f} "
            f"({r['anakin']['speedup_vs_loop']:.0f}x) "
            f"| {r['shard_map']['steps_per_sec']:,.0f} "
            f"| {sv['speedup']:.1f}x @ {sv['num_seeds']} seeds "
            f"| {fused_col} "
            f"| {async_col} |"
        )
    return "\n".join(lines) + "\n"
