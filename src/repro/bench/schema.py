"""Benchmark-artifact schema checks (BENCH_eval.json / BENCH_speed.json).

The two artifacts are the repo's measurement contract: every speed/scale PR
appends to them, and downstream tooling (CI assertions, plots, the README
tables) reads them by key. These checks pin the documented schema so a PR
that silently drops or renames a field fails CI instead of corrupting the
trajectory. Hand-rolled (no jsonschema dependency): each checker returns a
list of human-readable problems, empty when the document conforms.
"""
from __future__ import annotations

import json
from typing import Dict, List

_AGGREGATE_KEYS = (
    "mean", "median", "iqm", "std", "num_seeds", "num_episodes",
    "iqm_ci95", "mean_ci95",
)
_RUNNER_KEYS = ("python_loop", "anakin", "shard_map")
_SEEDVEC_KEYS = (
    "num_seeds", "serial_steps_per_sec", "vmapped_steps_per_sec", "speedup",
)


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_eval_schema(doc: Dict) -> List[str]:
    """Problems with a BENCH_eval.json document (schema in README.md)."""
    errs: List[str] = []
    for k in ("seeds", "num_episodes", "num_envs", "train_iterations", "systems"):
        if k not in doc:
            errs.append(f"missing top-level key {k!r}")
    if errs:
        return errs
    num_seeds, num_episodes = len(doc["seeds"]), doc["num_episodes"]
    if not isinstance(doc["systems"], dict) or not doc["systems"]:
        return ["'systems' must be a non-empty object"]
    for sys_name, entry in doc["systems"].items():
        envs = entry.get("envs")
        if not isinstance(envs, dict) or not envs:
            errs.append(f"systems.{sys_name}.envs must be a non-empty object")
            continue
        for env_name, cell in envs.items():
            where = f"systems.{sys_name}.envs.{env_name}"
            if not isinstance(cell.get("compatible"), bool):
                errs.append(f"{where}.compatible must be a bool")
                continue
            if not cell["compatible"]:
                if not isinstance(cell.get("reason"), str):
                    errs.append(f"{where}: incompatible cell needs a 'reason'")
                continue
            returns = cell.get("returns")
            if (
                not isinstance(returns, list)
                or len(returns) != num_seeds
                or any(len(row) != num_episodes for row in returns)
            ):
                errs.append(
                    f"{where}.returns must be a "
                    f"({num_seeds}, {num_episodes}) nested list"
                )
            agg = cell.get("aggregates", {})
            for k in _AGGREGATE_KEYS:
                if k not in agg:
                    errs.append(f"{where}.aggregates missing {k!r}")
            if not isinstance(cell.get("per_agent_mean"), dict):
                errs.append(f"{where}.per_agent_mean must be an object")
            for k in ("mean_episode_length", "steps_per_sec", "horizon"):
                if not _num(cell.get(k)):
                    errs.append(f"{where}.{k} must be a number")
    return errs


def check_speed_schema(doc: Dict) -> List[str]:
    """Problems with a BENCH_speed.json document (schema in README.md)."""
    errs: List[str] = []
    cfg = doc.get("config")
    if not isinstance(cfg, dict):
        errs.append("missing top-level 'config' object")
    else:
        for k in ("iterations", "num_envs", "num_seeds", "loop_episodes"):
            if not _num(cfg.get(k)):
                errs.append(f"config.{k} must be a number")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errs.append("'cells' must be a non-empty list")
        return errs
    for i, cell in enumerate(cells):
        where = f"cells[{i}]"
        for k in ("system", "env"):
            if not isinstance(cell.get(k), str):
                errs.append(f"{where}.{k} must be a string")
        if not isinstance(cell.get("compatible"), bool):
            errs.append(f"{where}.compatible must be a bool")
            continue
        if not cell["compatible"]:
            if not isinstance(cell.get("reason"), str):
                errs.append(f"{where}: incompatible cell needs a 'reason'")
            continue
        runners = cell.get("runners", {})
        for r in _RUNNER_KEYS:
            sps = runners.get(r, {}).get("steps_per_sec")
            if not _num(sps) or sps <= 0:
                errs.append(f"{where}.runners.{r}.steps_per_sec must be > 0")
        sv = cell.get("seed_vectorization", {})
        for k in _SEEDVEC_KEYS:
            if not _num(sv.get(k)):
                errs.append(f"{where}.seed_vectorization.{k} must be a number")
        if _num(sv.get("speedup")) and sv["speedup"] <= 0:
            errs.append(f"{where}.seed_vectorization.speedup must be > 0")
    return errs


def validate_path(path: str) -> List[str]:
    """Validate one artifact file, dispatching on its contents."""
    with open(path) as f:
        doc = json.load(f)
    if "cells" in doc:
        return check_speed_schema(doc)
    if "systems" in doc:
        return check_eval_schema(doc)
    return [f"{path}: neither a BENCH_eval (systems) nor BENCH_speed (cells) document"]
