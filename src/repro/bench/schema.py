"""Artifact schema checks: BENCH_eval / BENCH_speed / BENCH_serve / run records.

The benchmark artifacts are the repo's measurement contract: every
speed/scale PR appends to them, and downstream tooling (CI assertions,
plots, the README tables) reads them by key. These checks pin the
documented schema so a PR that silently drops or renames a field fails CI
instead of corrupting the trajectory.  The same discipline covers the
``repro.obs`` telemetry artifacts: every document carries a provenance
block (`check_provenance`) and per-run ``run.json`` records conform to
`check_run_record` (schema in docs/OBSERVABILITY.md). Hand-rolled (no
jsonschema dependency): each checker returns a list of human-readable
problems, empty when the document conforms.
"""
from __future__ import annotations

import json
from typing import Dict, List

_AGGREGATE_KEYS = (
    "mean", "median", "iqm", "std", "num_seeds", "num_episodes",
    "iqm_ci95", "mean_ci95",
)
_RUNNER_KEYS = ("python_loop", "anakin", "shard_map")
_SEEDVEC_KEYS = (
    "num_seeds", "serial_steps_per_sec", "vmapped_steps_per_sec", "speedup",
)
# the optional per-cell fused-recurrent rung (recurrent systems only):
# anakin with the linear associative-scan core vs the reference GRU core
_FUSED_RECURRENT_NUM_KEYS = (
    "reference_steps_per_sec", "fused_steps_per_sec", "speedup",
)
# the optional per-cell async actor/learner rung (repro.distributed.impala):
# one row per actor count, throughput scaling with actor replicas
_ASYNC_ROW_NUM_KEYS = ("num_actors", "steps_per_sec", "env_steps", "wall_seconds")
# the provenance block (produced by repro.obs.record.provenance) required
# on every artifact: string fields + the device count
_PROVENANCE_STR_KEYS = (
    "git_sha", "jax_version", "backend", "device_kind", "timestamp",
)
# the required sections of a run record (repro.obs.record.RunRecord)
_RUN_RECORD_SECTIONS = ("provenance", "config", "timing", "metrics")
_RUN_TIMING_KEYS = ("total_seconds", "compile_seconds", "steady_seconds")
_RUN_RETRACE_KEYS = ("jaxpr_traces", "backend_compiles", "compile_seconds")

# The coverage pins for the *checked-in* artifacts (smoke runs in CI emit
# partial slices and are validated without them). Literal copies of the
# registries — this module stays import-free of jax so the lint job can
# file-load it — so growing either registry means growing these tuples in
# the same PR, which is exactly the tripwire: a new system/env that never
# lands in the committed matrix fails `--full` validation.
FULL_MATRIX_SYSTEMS = (
    "dial", "ippo", "mad4pg", "maddpg", "madqn", "madqn-fp", "mappo",
    "qmix", "rec_ippo", "rec_madqn", "rec_mappo", "rial", "vdn",
)
FULL_MATRIX_ENVS = (
    "lbf", "matrix_game", "robot_warehouse", "smax_lite",
    "speaker_listener", "spread", "switch_game",
)
SPEED_SLICE_SYSTEMS = ("vdn", "ippo", "rec_ippo")
# the checked-in async actor/learner coverage: every runnable speed-slice
# cell must carry an async_actors rung at exactly these actor counts, and
# at least MIN_ASYNC_MONOTONIC_CELLS of them must show steps/sec increasing
# monotonically with actor count (the rung's whole point: throughput scales
# with actor replicas instead of being bound by the lockstep scan)
ASYNC_ACTOR_COUNTS = (1, 2, 4)
MIN_ASYNC_MONOTONIC_CELLS = 2
# the checked-in fused-recurrent coverage: the recurrent speed-slice system
# must carry a fused_recurrent rung on the matrix game plus one gridworld,
# so the rec/ff gap number stays comparable across PRs
FUSED_RECURRENT_SYSTEM = "rec_ippo"
FUSED_RECURRENT_ENVS = ("matrix_game", "lbf")
# BENCH_serve's checked-in coverage: a feed-forward and a recurrent system
# must each be served at >= MIN_SERVE_SLOT_COUNTS distinct slot-pool sizes
# (the artifact's whole point is latency/throughput *vs slot count*)
SERVE_SLICE_SYSTEMS = ("ippo", "rec_ippo")
MIN_SERVE_SLOT_COUNTS = 2
_SERVE_CONFIG_NUM_KEYS = (
    "streams", "episodes_per_stream", "arrival_rate", "seed",
)
_SERVE_LATENCY_KEYS = ("p50_ms", "p99_ms", "mean_ms")
_SERVE_CELL_NUM_KEYS = ("ticks", "decisions", "episodes", "wall_seconds")


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_provenance(doc: Dict) -> List[str]:
    """Problems with a document's ``provenance`` block.

    Every artifact (BENCH_eval / BENCH_speed / run records) must say where
    it came from: git sha, jax version, backend + device kind, device
    count and a timestamp — the block `repro.obs.record.provenance`
    emits, pinned here so artifacts can't silently drop their origin.
    """
    errs: List[str] = []
    prov = doc.get("provenance")
    if not isinstance(prov, dict):
        return ["missing top-level 'provenance' object"]
    for k in _PROVENANCE_STR_KEYS:
        if not isinstance(prov.get(k), str) or not prov.get(k):
            errs.append(f"provenance.{k} must be a non-empty string")
    if not _num(prov.get("num_devices")):
        errs.append("provenance.num_devices must be a number")
    return errs


def check_run_record(doc: Dict) -> List[str]:
    """Problems with a ``run.json`` run record (schema in
    docs/OBSERVABILITY.md).

    Required: ``run_id``, the provenance block, a ``config`` object, a
    ``timing`` object with the total/compile/steady wall split, and a
    ``metrics`` object.  Optional sections are type-checked when present:
    ``timing.phases`` (numbers), ``retrace`` (the `RetraceCounter`
    summary) and ``profile`` (``trace_dir`` + optional roofline numbers).
    """
    errs: List[str] = []
    if not isinstance(doc.get("run_id"), str) or not doc.get("run_id"):
        errs.append("run_id must be a non-empty string")
    for section in _RUN_RECORD_SECTIONS:
        if not isinstance(doc.get(section), dict):
            errs.append(f"missing section {section!r} (must be an object)")
    if errs:
        return errs
    errs.extend(check_provenance(doc))
    timing = doc["timing"]
    for k in _RUN_TIMING_KEYS:
        if not _num(timing.get(k)):
            errs.append(f"timing.{k} must be a number")
    phases = timing.get("phases")
    if phases is not None:
        if not isinstance(phases, dict):
            errs.append("timing.phases must be an object")
        else:
            for k, v in phases.items():
                if not _num(v):
                    errs.append(f"timing.phases.{k} must be a number")
    retrace = doc.get("retrace")
    if retrace is not None:
        for k in _RUN_RETRACE_KEYS:
            if not _num(retrace.get(k)):
                errs.append(f"retrace.{k} must be a number")
    profile = doc.get("profile")
    if profile is not None:
        if not isinstance(profile.get("trace_dir"), str):
            errs.append("profile.trace_dir must be a string")
        roofline = profile.get("roofline")
        if roofline is not None:
            for k in ("hlo_flops", "hlo_bytes", "collective_bytes"):
                if not _num(roofline.get(k)):
                    errs.append(f"profile.roofline.{k} must be a number")
    return errs


def check_eval_schema(doc: Dict) -> List[str]:
    """Problems with a BENCH_eval.json document (schema in docs/BENCH.md)."""
    errs: List[str] = list(check_provenance(doc))
    for k in ("seeds", "num_episodes", "num_envs", "train_iterations", "systems"):
        if k not in doc:
            errs.append(f"missing top-level key {k!r}")
    if errs:
        return errs
    num_seeds, num_episodes = len(doc["seeds"]), doc["num_episodes"]
    if not isinstance(doc["systems"], dict) or not doc["systems"]:
        return ["'systems' must be a non-empty object"]
    for sys_name, entry in doc["systems"].items():
        envs = entry.get("envs")
        if not isinstance(envs, dict) or not envs:
            errs.append(f"systems.{sys_name}.envs must be a non-empty object")
            continue
        for env_name, cell in envs.items():
            where = f"systems.{sys_name}.envs.{env_name}"
            if not isinstance(cell.get("compatible"), bool):
                errs.append(f"{where}.compatible must be a bool")
                continue
            if not cell["compatible"]:
                if not isinstance(cell.get("reason"), str):
                    errs.append(f"{where}: incompatible cell needs a 'reason'")
                continue
            returns = cell.get("returns")
            if (
                not isinstance(returns, list)
                or len(returns) != num_seeds
                or any(len(row) != num_episodes for row in returns)
            ):
                errs.append(
                    f"{where}.returns must be a "
                    f"({num_seeds}, {num_episodes}) nested list"
                )
            agg = cell.get("aggregates", {})
            for k in _AGGREGATE_KEYS:
                if k not in agg:
                    errs.append(f"{where}.aggregates missing {k!r}")
            if not isinstance(cell.get("per_agent_mean"), dict):
                errs.append(f"{where}.per_agent_mean must be an object")
            for k in ("mean_episode_length", "steps_per_sec", "horizon"):
                if not _num(cell.get(k)):
                    errs.append(f"{where}.{k} must be a number")
    return errs


def check_speed_schema(doc: Dict) -> List[str]:
    """Problems with a BENCH_speed.json document (schema in docs/BENCH.md)."""
    errs: List[str] = list(check_provenance(doc))
    cfg = doc.get("config")
    if not isinstance(cfg, dict):
        errs.append("missing top-level 'config' object")
    else:
        for k in ("iterations", "num_envs", "num_seeds", "loop_episodes"):
            if not _num(cfg.get(k)):
                errs.append(f"config.{k} must be a number")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errs.append("'cells' must be a non-empty list")
        return errs
    for i, cell in enumerate(cells):
        where = f"cells[{i}]"
        for k in ("system", "env"):
            if not isinstance(cell.get(k), str):
                errs.append(f"{where}.{k} must be a string")
        if not isinstance(cell.get("compatible"), bool):
            errs.append(f"{where}.compatible must be a bool")
            continue
        if not cell["compatible"]:
            if not isinstance(cell.get("reason"), str):
                errs.append(f"{where}: incompatible cell needs a 'reason'")
            continue
        runners = cell.get("runners", {})
        for r in _RUNNER_KEYS:
            sps = runners.get(r, {}).get("steps_per_sec")
            if not _num(sps) or sps <= 0:
                errs.append(f"{where}.runners.{r}.steps_per_sec must be > 0")
        sv = cell.get("seed_vectorization", {})
        for k in _SEEDVEC_KEYS:
            if not _num(sv.get(k)):
                errs.append(f"{where}.seed_vectorization.{k} must be a number")
        if _num(sv.get("speedup")) and sv["speedup"] <= 0:
            errs.append(f"{where}.seed_vectorization.speedup must be > 0")
        fr = cell.get("fused_recurrent")
        if fr is not None:
            for k in ("core", "reference_core"):
                if not isinstance(fr.get(k), str) or not fr.get(k):
                    errs.append(
                        f"{where}.fused_recurrent.{k} must be a non-empty string"
                    )
            for k in _FUSED_RECURRENT_NUM_KEYS:
                if not _num(fr.get(k)) or fr.get(k, 0) <= 0:
                    errs.append(f"{where}.fused_recurrent.{k} must be > 0")
        aa = cell.get("async_actors")
        if aa is not None:
            errs.extend(_check_async_block(aa, where))
    return errs


def _check_async_block(aa, where: str) -> List[str]:
    """Problems with one cell's ``async_actors`` block (docs/BENCH.md)."""
    errs: List[str] = []
    where = f"{where}.async_actors"
    if not isinstance(aa, dict):
        return [f"{where} must be an object"]
    counts = aa.get("actor_counts")
    if not isinstance(counts, list) or not all(_num(c) for c in counts):
        errs.append(f"{where}.actor_counts must be a list of numbers")
        counts = []
    for k in ("param_sync_every", "unroll_len"):
        if not _num(aa.get(k)) or aa.get(k, 0) < 1:
            errs.append(f"{where}.{k} must be a number >= 1")
    rows = aa.get("cells")
    if not isinstance(rows, list) or len(rows) != len(counts):
        errs.append(f"{where}.cells must be a list matching actor_counts")
        return errs
    for j, (count, row) in enumerate(zip(counts, rows)):
        rwhere = f"{where}.cells[{j}]"
        if not isinstance(row, dict):
            errs.append(f"{rwhere} must be an object")
            continue
        if row.get("num_actors") != count:
            errs.append(
                f"{rwhere}.num_actors must equal actor_counts[{j}] ({count})"
            )
        for k in _ASYNC_ROW_NUM_KEYS:
            if not _num(row.get(k)) or row.get(k, 0) <= 0:
                errs.append(f"{rwhere}.{k} must be > 0")
    return errs


def check_serve_schema(doc: Dict) -> List[str]:
    """Problems with a BENCH_serve.json document (schema in docs/BENCH.md).

    A serving artifact declares itself with ``"workload": "serve"`` and
    carries the provenance block, the traffic config (streams, episodes
    per stream, arrival rate, seed, mode) and one cell per
    (checkpoint, slot count) pair: per-decision latency percentiles,
    decisions/sec and episode counts for a restored policy served behind
    a `repro.serve.DecisionEngine` slot pool.
    """
    errs: List[str] = list(check_provenance(doc))
    if doc.get("workload") != "serve":
        errs.append("'workload' must be the string 'serve'")
    cfg = doc.get("config")
    if not isinstance(cfg, dict):
        errs.append("missing top-level 'config' object")
    else:
        for k in _SERVE_CONFIG_NUM_KEYS:
            if not _num(cfg.get(k)):
                errs.append(f"config.{k} must be a number")
        if cfg.get("mode") not in ("greedy", "sample"):
            errs.append("config.mode must be 'greedy' or 'sample'")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errs.append("'cells' must be a non-empty list")
        return errs
    for i, cell in enumerate(cells):
        where = f"cells[{i}]"
        for k in ("system", "env", "checkpoint"):
            if not isinstance(cell.get(k), str) or not cell.get(k):
                errs.append(f"{where}.{k} must be a non-empty string")
        if not _num(cell.get("max_slots")) or cell.get("max_slots", 0) < 1:
            errs.append(f"{where}.max_slots must be a number >= 1")
        for k in _SERVE_CELL_NUM_KEYS:
            if not _num(cell.get(k)):
                errs.append(f"{where}.{k} must be a number")
        if not _num(cell.get("decisions_per_sec")) or cell.get(
            "decisions_per_sec", 0
        ) <= 0:
            errs.append(f"{where}.decisions_per_sec must be > 0")
        if not _num(cell.get("episode_return_mean")):
            errs.append(f"{where}.episode_return_mean must be a number")
        lat = cell.get("latency")
        if not isinstance(lat, dict):
            errs.append(f"{where}.latency must be an object")
            continue
        for k in _SERVE_LATENCY_KEYS:
            if not _num(lat.get(k)) or lat.get(k, 0) <= 0:
                errs.append(f"{where}.latency.{k} must be > 0")
        if (
            _num(lat.get("p50_ms"))
            and _num(lat.get("p99_ms"))
            and lat["p99_ms"] < lat["p50_ms"]
        ):
            errs.append(f"{where}.latency.p99_ms must be >= p50_ms")
    return errs


def check_serve_slice(doc: Dict) -> List[str]:
    """Schema plus coverage of the checked-in serving slice.

    The committed ``BENCH_serve.json`` must serve a feed-forward and a
    recurrent system (`SERVE_SLICE_SYSTEMS`) at `MIN_SERVE_SLOT_COUNTS`+
    distinct slot counts each — the two axes the subsystem exists to
    measure.  CI smoke runs validate with `check_serve_schema` alone.
    """
    errs = check_serve_schema(doc)
    cells = doc.get("cells")
    if not isinstance(cells, list):
        return errs
    for s in SERVE_SLICE_SYSTEMS:
        slot_counts = {
            c.get("max_slots") for c in cells
            if isinstance(c, dict) and c.get("system") == s
        }
        if len(slot_counts) < MIN_SERVE_SLOT_COUNTS:
            errs.append(
                f"serve slice needs system {s!r} at >= "
                f"{MIN_SERVE_SLOT_COUNTS} slot counts (got "
                f"{sorted(slot_counts, key=str)})"
            )
    return errs


def check_eval_full_matrix(doc: Dict) -> List[str]:
    """Schema plus coverage: every registered (system, env) cell present.

    The pin for the checked-in ``BENCH_eval.json``: the artifact must span
    the full `FULL_MATRIX_SYSTEMS` x `FULL_MATRIX_ENVS` matrix (runnable
    or reasoned-incompatible), so registry growth without a regenerated
    matrix fails CI.
    """
    errs = check_eval_schema(doc)
    systems = doc.get("systems", {})
    if not isinstance(systems, dict):
        return errs
    for s in FULL_MATRIX_SYSTEMS:
        if s not in systems:
            errs.append(f"full matrix missing system {s!r}")
            continue
        envs = systems[s].get("envs", {})
        for e in FULL_MATRIX_ENVS:
            if e not in envs:
                errs.append(f"full matrix missing cell ({s}, {e})")
    return errs


def check_speed_full_matrix(doc: Dict) -> List[str]:
    """Schema plus coverage of the default throughput slice.

    The checked-in ``BENCH_speed.json`` must carry a row per system in
    `SPEED_SLICE_SYSTEMS` (one replay, one on-policy, one recurrent
    family), keeping the perf trajectory comparable across PRs.  Every
    runnable slice cell must additionally carry an ``async_actors`` rung
    at exactly `ASYNC_ACTOR_COUNTS`, with at least
    `MIN_ASYNC_MONOTONIC_CELLS` cells showing steps/sec monotonically
    increasing with actor count.
    """
    errs = check_speed_schema(doc)
    cells = doc.get("cells")
    have = {c.get("system") for c in cells} if isinstance(cells, list) else set()
    for s in SPEED_SLICE_SYSTEMS:
        if s not in have:
            errs.append(f"speed slice missing system {s!r}")
    if isinstance(cells, list):
        fused_envs = {
            c.get("env") for c in cells
            if isinstance(c, dict)
            and c.get("system") == FUSED_RECURRENT_SYSTEM
            and isinstance(c.get("fused_recurrent"), dict)
        }
        for e in FUSED_RECURRENT_ENVS:
            if e not in fused_envs:
                errs.append(
                    f"speed slice missing fused_recurrent rung for "
                    f"({FUSED_RECURRENT_SYSTEM}, {e})"
                )
        monotonic = 0
        for c in cells:
            if not (isinstance(c, dict) and c.get("compatible")):
                continue
            if c.get("system") not in SPEED_SLICE_SYSTEMS:
                continue
            aa = c.get("async_actors")
            where = f"({c.get('system')}, {c.get('env')})"
            if not isinstance(aa, dict):
                errs.append(f"speed slice missing async_actors rung for {where}")
                continue
            if tuple(aa.get("actor_counts", ())) != ASYNC_ACTOR_COUNTS:
                errs.append(
                    f"{where}.async_actors.actor_counts must be "
                    f"{list(ASYNC_ACTOR_COUNTS)}"
                )
                continue
            sps = [row.get("steps_per_sec", 0) for row in aa.get("cells", [])]
            if len(sps) == len(ASYNC_ACTOR_COUNTS) and all(
                b > a for a, b in zip(sps, sps[1:])
            ):
                monotonic += 1
        if monotonic < MIN_ASYNC_MONOTONIC_CELLS:
            errs.append(
                f"async_actors rung must scale monotonically over "
                f"{list(ASYNC_ACTOR_COUNTS)} actors on >= "
                f"{MIN_ASYNC_MONOTONIC_CELLS} speed-slice cells "
                f"(got {monotonic})"
            )
    return errs


def validate_path(path: str, full: bool = False) -> List[str]:
    """Validate one artifact file, dispatching on its contents.

    Dispatch: ``run_id`` marks a run record, ``workload: "serve"`` a
    BENCH_serve document, ``cells`` a BENCH_speed document, ``systems`` a
    BENCH_eval document.  ``full`` additionally enforces the checked-in
    coverage pins (`check_eval_full_matrix` / `check_speed_full_matrix` /
    `check_serve_slice`) — used for the committed artifacts, not the
    partial CI smoke slices (run records have no coverage pin).
    """
    with open(path) as f:
        doc = json.load(f)
    if "run_id" in doc:
        return check_run_record(doc)
    if doc.get("workload") == "serve":
        return check_serve_slice(doc) if full else check_serve_schema(doc)
    if "cells" in doc:
        return check_speed_full_matrix(doc) if full else check_speed_schema(doc)
    if "systems" in doc:
        return check_eval_full_matrix(doc) if full else check_eval_schema(doc)
    return [
        f"{path}: not a run record (run_id), BENCH_serve (workload), "
        "BENCH_eval (systems) or BENCH_speed (cells) document"
    ]
