"""repro.bench: the throughput half of the measurement backbone.

`repro.eval` answers "how well does it play"; this package answers "how
fast does it train" — steps/sec for each runner rung (python loop, fused
Anakin, shard_map) and the serial-vs-vmapped-seed speedup, emitted as the
``BENCH_speed.json`` perf-trajectory artifact by `repro.launch.bench_marl`.
"""
from repro.bench.schema import (
    check_eval_schema,
    check_speed_schema,
    validate_path,
)
from repro.bench.throughput import (
    SMOKE_OVERRIDES,
    bench_cell,
    measure_anakin,
    measure_python_loop,
    measure_seed_vectorization,
    measure_shard_map,
    run_bench,
    smoke_overrides,
    to_markdown,
)

__all__ = [
    "SMOKE_OVERRIDES",
    "bench_cell",
    "smoke_overrides",
    "check_eval_schema",
    "check_speed_schema",
    "measure_anakin",
    "measure_python_loop",
    "measure_seed_vectorization",
    "measure_shard_map",
    "run_bench",
    "to_markdown",
    "validate_path",
]
