"""Scenario-sweep harness: systems x environments, registry-driven.

The marl-jax idiom: a single command evaluates any set of registered
systems across all scenarios in ``repro.envs.REGISTRY`` over multiple
seeds and reports per-cell robust aggregates (IQM + stratified-bootstrap
95% CI, via `repro.eval.stats`) and eval throughput — the measurement
backbone every speed/scale PR reports against.

Every (system, env) cell of the support matrix is emitted: runnable cells
carry scores, incompatible ones carry the spec-driven reason (from
``repro.systems.registry.compatibility``), so the artifact doubles as the
library's compatibility matrix.

Artifacts: ``BENCH_eval.json`` (schema documented in docs/BENCH.md) and a
markdown table next to it.
"""
from __future__ import annotations

import json
import math
import pathlib
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.system import train_anakin
from repro.envs import REGISTRY as ENV_REGISTRY
from repro.eval.evaluator import make_evaluator
from repro.eval.stats import aggregate
from repro.obs import ConsoleSink, provenance
from repro.systems.registry import REGISTRY as SYS_REGISTRY
from repro.systems.registry import compatibility, make_pair

# the sweep's human-facing reporting path (one formatting pipeline for
# every launcher — see repro.obs.sinks)
_console = ConsoleSink()


def evaluate_on_env(
    system,
    seeds: Sequence[int],
    num_episodes: int,
    num_envs: int,
    train_iterations: int = 0,
    train_num_envs: int = 8,
) -> Dict[str, object]:
    """Evaluate one system on its env over `seeds`; returns the JSON cell.

    All seeds run vectorized: training is one seed-vmapped `train_anakin`
    program and evaluation one vmapped evaluator call, so the whole cell
    compiles exactly twice (once each) instead of once per seed.  Per-seed
    keys are threaded as a stacked traced key batch — seed ``s`` sees
    exactly the ``jax.random.key(s)`` stream the serial loop used, so the
    per-seed returns are unchanged.
    """
    num_seeds = len(seeds)
    eval_fn = jax.jit(jax.vmap(make_evaluator(system, num_episodes, num_envs)))
    horizon = int(system.env.horizon)
    eff_envs = min(num_envs, num_episodes)
    steps_per_call = math.ceil(num_episodes / eff_envs) * eff_envs * horizon

    keys = jnp.stack([jax.random.key(int(s)) for s in seeds])
    split = jax.vmap(jax.random.split)(keys)  # (num_seeds, 2)
    k_train, k_eval = split[:, 0], split[:, 1]
    if train_iterations > 0:
        st, _ = train_anakin(
            system, k_train, train_iterations, train_num_envs,
            num_seeds=num_seeds,
        )
        train = st.train
    else:
        train = jax.vmap(system.init_train)(k_train)

    metrics = jax.block_until_ready(eval_fn(train, k_eval))  # warm compile
    best = float("inf")
    for _ in range(3):  # best-of-3: scheduler noise swamps ms-scale eval calls
        t0 = time.perf_counter()
        metrics = jax.block_until_ready(eval_fn(train, k_eval))
        best = min(best, time.perf_counter() - t0)
    sps = num_seeds * steps_per_call / best

    team = np.asarray(metrics.episode_return)  # (num_seeds, num_episodes)
    return {
        "compatible": True,
        "returns": team.tolist(),
        "aggregates": aggregate(team),
        "per_agent_mean": {
            a: float(np.mean(np.asarray(r)))
            for a, r in metrics.agent_returns.items()
        },
        "mean_episode_length": float(np.mean(np.asarray(metrics.episode_length))),
        "steps_per_sec": float(sps),
        "horizon": horizon,
    }


def run_sweep(
    system_names: Optional[Sequence[str]] = None,
    env_names: Optional[Sequence[str]] = None,
    seeds: Sequence[int] = (0, 1, 2),
    num_episodes: int = 32,
    num_envs: int = 16,
    train_iterations: int = 0,
    out_path: str = "BENCH_eval.json",
    system_overrides: Optional[dict] = None,
) -> Dict[str, object]:
    """Sweep systems x envs; write BENCH_eval.json + markdown.

    Incompatible cells are recorded with their reason rather than skipped
    silently, so the artifact carries the full support matrix.
    ``system_overrides`` maps system name -> config-field overrides (used
    by tests/CI to shrink replay sizes etc.).
    """
    system_names = list(system_names) if system_names else sorted(SYS_REGISTRY)
    env_names = list(env_names) if env_names else sorted(ENV_REGISTRY)
    overrides = system_overrides or {}
    results: Dict[str, object] = {
        "provenance": provenance(),
        "seeds": list(seeds),
        "num_episodes": num_episodes,
        "num_envs": num_envs,
        "train_iterations": train_iterations,
        "systems": {},
    }
    for sys_name in system_names:
        per_env: Dict[str, object] = {}
        results["systems"][sys_name] = {"envs": per_env}
        for env_name in env_names:
            t0 = time.perf_counter()
            reason = compatibility(sys_name, env_name)
            if reason is not None:
                per_env[env_name] = {"compatible": False, "reason": reason}
                _console.line(f"{sys_name:>10s} x {env_name:<18s}: skipped ({reason})")
                continue
            _, system = make_pair(
                sys_name, env_name, **overrides.get(sys_name, {})
            )
            cell = evaluate_on_env(
                system, seeds, num_episodes, num_envs, train_iterations
            )
            per_env[env_name] = cell
            agg = cell["aggregates"]
            lo, hi = agg["iqm_ci95"]
            _console.line(
                f"{sys_name:>10s} x {env_name:<18s}: IQM={agg['iqm']:8.3f} "
                f"[{lo:.3f}, {hi:.3f}]  mean={agg['mean']:8.3f}  "
                f"{cell['steps_per_sec']:,.0f} steps/s  "
                f"({time.perf_counter() - t0:.1f}s)"
            )

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    md_path = str(pathlib.Path(out_path).with_suffix(".md"))
    with open(md_path, "w") as f:
        f.write(to_markdown(results))
    _console.line(f"wrote {out_path} and {md_path}")
    return results


def to_markdown(results: Dict[str, object]) -> str:
    """Render the sweep as a systems x envs support/score matrix."""
    systems = list(results["systems"])
    env_names = sorted(
        {e for s in systems for e in results["systems"][s]["envs"]}
    )
    lines = [
        "# Evaluation sweep — systems x environments",
        "",
        f"{len(results['seeds'])} seeds x {results['num_episodes']} episodes "
        f"per cell, {results['train_iterations']} training iterations. "
        "Cells show IQM of team return [95% CI]; `--` marks incompatible "
        "(system, env) pairs.",
        "",
        "| system | " + " | ".join(env_names) + " |",
        "|---|" + "---|" * len(env_names),
    ]
    for sys_name in systems:
        cells = []
        for env_name in env_names:
            cell = results["systems"][sys_name]["envs"].get(env_name)
            if cell is None or not cell.get("compatible"):
                cells.append("--")
                continue
            agg = cell["aggregates"]
            lo, hi = agg["iqm_ci95"]
            cells.append(f"{agg['iqm']:.2f} [{lo:.2f}, {hi:.2f}]")
        lines.append(f"| {sys_name} | " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"
