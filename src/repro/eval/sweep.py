"""Scenario-sweep harness: one system x every registered environment.

The marl-jax idiom: a single command evaluates a system across all scenarios
in ``repro.envs.REGISTRY`` over multiple seeds and reports a per-scenario
table with robust aggregates (IQM + stratified-bootstrap 95% CI, via
`repro.eval.stats`) and eval throughput — the measurement backbone every
speed/scale PR reports against.

Artifacts: ``BENCH_eval.json`` (schema documented in README.md) and a
markdown table next to it.
"""
from __future__ import annotations

import json
import math
import time
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from repro.core.system import train_anakin
from repro.envs import REGISTRY, make_env
from repro.eval.evaluator import make_evaluator
from repro.eval.stats import aggregate


def evaluate_on_env(
    system,
    seeds: Sequence[int],
    num_episodes: int,
    num_envs: int,
    train_iterations: int = 0,
    train_num_envs: int = 8,
) -> Dict[str, object]:
    """Evaluate one system on its env over `seeds`; returns the JSON cell."""
    eval_fn = jax.jit(make_evaluator(system, num_episodes, num_envs))
    horizon = int(system.env.horizon)
    eff_envs = min(num_envs, num_episodes)
    steps_per_call = math.ceil(num_episodes / eff_envs) * eff_envs * horizon

    team_scores, agent_scores, lengths, sps = [], {}, [], []
    for seed in seeds:
        key = jax.random.key(seed)
        k_train, k_eval = jax.random.split(key)
        if train_iterations > 0:
            st, _ = train_anakin(system, k_train, train_iterations, train_num_envs)
            train = st.train
        else:
            train = system.init_train(k_train)

        metrics = jax.block_until_ready(eval_fn(train, k_eval))  # warm compile
        t0 = time.perf_counter()
        metrics = jax.block_until_ready(eval_fn(train, k_eval))
        sps.append(steps_per_call / (time.perf_counter() - t0))

        team_scores.append(np.asarray(metrics.episode_return))
        lengths.append(np.asarray(metrics.episode_length))
        for a, r in metrics.agent_returns.items():
            agent_scores.setdefault(a, []).append(np.asarray(r))

    team = np.stack(team_scores)  # (num_seeds, num_episodes)
    return {
        "returns": team.tolist(),
        "aggregates": aggregate(team),
        "per_agent_mean": {
            a: float(np.mean(np.stack(rs))) for a, rs in agent_scores.items()
        },
        "mean_episode_length": float(np.mean(np.stack(lengths))),
        "steps_per_sec": float(np.median(sps)),
        "horizon": horizon,
    }


def run_sweep(
    system_name: str,
    make_system,
    env_names: Optional[Sequence[str]] = None,
    seeds: Sequence[int] = (0, 1, 2),
    num_episodes: int = 32,
    num_envs: int = 16,
    train_iterations: int = 0,
    out_path: str = "BENCH_eval.json",
) -> Dict[str, object]:
    """Sweep `system_name` across envs; write BENCH_eval.json + markdown.

    ``make_system(env) -> System`` builds the system for each scenario.
    """
    env_names = list(env_names) if env_names else sorted(REGISTRY)
    results: Dict[str, object] = {
        "system": system_name,
        "seeds": list(seeds),
        "num_episodes": num_episodes,
        "num_envs": num_envs,
        "train_iterations": train_iterations,
        "envs": {},
    }
    for name in env_names:
        t0 = time.perf_counter()
        system = make_system(make_env(name))
        cell = evaluate_on_env(
            system, seeds, num_episodes, num_envs, train_iterations
        )
        results["envs"][name] = cell
        agg = cell["aggregates"]
        lo, hi = agg["iqm_ci95"]
        print(
            f"{name:>18s}: IQM={agg['iqm']:8.3f} [{lo:.3f}, {hi:.3f}]  "
            f"mean={agg['mean']:8.3f}  {cell['steps_per_sec']:,.0f} steps/s  "
            f"({time.perf_counter() - t0:.1f}s)"
        )

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    md_path = out_path.rsplit(".", 1)[0] + ".md"
    with open(md_path, "w") as f:
        f.write(to_markdown(results))
    print(f"wrote {out_path} and {md_path}")
    return results


def to_markdown(results: Dict[str, object]) -> str:
    """Render the sweep results as a per-scenario markdown table."""
    lines = [
        f"# `{results['system']}` evaluation sweep",
        "",
        f"{len(results['seeds'])} seeds x {results['num_episodes']} episodes "
        f"per env, {results['train_iterations']} training iterations.",
        "",
        "| env | IQM | 95% CI | mean | median | eval steps/s |",
        "|---|---|---|---|---|---|",
    ]
    for name, cell in results["envs"].items():
        agg = cell["aggregates"]
        lo, hi = agg["iqm_ci95"]
        lines.append(
            f"| {name} | {agg['iqm']:.3f} | [{lo:.3f}, {hi:.3f}] | "
            f"{agg['mean']:.3f} | {agg['median']:.3f} | "
            f"{cell['steps_per_sec']:,.0f} |"
        )
    return "\n".join(lines) + "\n"
