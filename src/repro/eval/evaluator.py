"""Jit-fused, vmapped greedy-policy evaluation.

The JaxMARL lesson: once training is an Anakin-style fused scan, evaluation
must be fused too or it becomes the bottleneck (and a host round trip breaks
the single-program property).  The evaluator here is a pure function of
``(train_state, key)`` so it composes both ways:

  * standalone — ``evaluate(system, params, key, ...)`` jit-compiles one
    call and returns `EvalMetrics` on the host;
  * interleaved — ``make_evaluator(system, ...)`` returns the same pure
    function for splicing into ``train_anakin`` / ``train_distributed``'s
    scan, so periodic eval runs *inside* the training jit.

Episodes are fixed-length lax.scans of ``env.horizon`` steps across
``num_envs`` vmapped env copies; early-terminating envs are handled by
masking rewards after the first LAST step (no auto-reset — each env copy is
exactly one episode).  Actions are greedy (``training=False``).

Recurrent systems are first-class: the executor carry
(`repro.core.types.Carry` — GRU hidden state, comm messages) starts at
``initial_carry((num_envs,))`` and is threaded across every step of the
episode scan, one memory slot per env copy, vmapped over seeds when the
caller asks for a seed axis.  Each env copy runs exactly one episode, so
no mid-scan resets are needed, and greedy returns are invariant to how
episodes are chunked across ``num_envs`` (pinned by
``tests/test_recurrent.py``).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.types import EvalMetrics, TrainState
from repro.envs.api import StepType


def _as_train_state(params_or_train) -> TrainState:
    """Accept a full TrainState or bare params (wrapped with zero steps)."""
    if isinstance(params_or_train, TrainState):
        return params_or_train
    return TrainState(
        params=params_or_train,
        target_params=params_or_train,
        opt_state=None,
        steps=jnp.zeros((), jnp.int32),
    )


def _episode_batch(system, train: TrainState, key, num_envs: int, horizon: int):
    """Roll one batch of `num_envs` complete greedy episodes.

    Returns (team_return (B,), agent_returns {a: (B,)}, length (B,)).
    """
    env = system.env
    ids = list(system.spec.agent_ids)
    k_reset, k_steps = jax.random.split(key)
    env_state, ts = jax.vmap(env.reset)(jax.random.split(k_reset, num_envs))
    carry = system.initial_carry((num_envs,))

    zeros = jnp.zeros((num_envs,))
    init = (
        env_state,
        ts,
        carry,
        jnp.zeros((num_envs,), bool),          # done: episode already over
        {a: zeros for a in ids},               # per-agent return accumulators
        jnp.zeros((num_envs,), jnp.int32),     # episode length
    )

    def step(sc, k_act):
        """One greedy vectorised env step with reward/length masking."""
        env_state, ts, carry, done, rets, length = sc
        gs = jax.vmap(env.global_state)(env_state)
        actions, carry, _ = system.select_actions(
            train, ts.observation, gs, carry, k_act, training=False
        )
        env_state, new_ts = jax.vmap(env.step)(env_state, actions)
        alive = ~done
        rets = {
            a: rets[a] + jnp.where(alive, new_ts.reward[a], 0.0) for a in ids
        }
        length = length + alive.astype(jnp.int32)
        done = done | (new_ts.step_type == StepType.LAST)
        return (env_state, new_ts, carry, done, rets, length), None

    keys = jax.random.split(k_steps, horizon)
    (_, _, _, _, rets, length), _ = jax.lax.scan(step, init, keys)
    team = jnp.mean(jnp.stack([rets[a] for a in ids]), axis=0)
    return team, rets, length


def make_evaluator(
    system,
    num_episodes: int = 32,
    num_envs: int = 16,
) -> Callable[[Any, Any], EvalMetrics]:
    """Build the pure eval function ``(train_or_params, key) -> EvalMetrics``.

    Jit-compatible: splice it into a training scan for interleaved eval, or
    wrap it in `jax.jit` yourself (which is all `evaluate` does).
    """
    if num_episodes < 1 or num_envs < 1:
        raise ValueError(
            f"num_episodes ({num_episodes}) and num_envs ({num_envs}) must "
            "be >= 1"
        )
    num_envs = min(num_envs, num_episodes)
    num_rounds = math.ceil(num_episodes / num_envs)
    ids = list(system.spec.agent_ids)
    horizon = int(system.env.horizon)

    def eval_fn(train_or_params, key) -> EvalMetrics:
        """The pure evaluator: ``(train_or_params, key) -> EvalMetrics``."""
        train = _as_train_state(train_or_params)

        def one_round(key, _):
            """One batch of ``num_envs`` episodes (scanned ``num_rounds`` times)."""
            key, kr = jax.random.split(key)
            return key, _episode_batch(system, train, kr, num_envs, horizon)

        _, (team, rets, length) = jax.lax.scan(
            one_round, key, None, length=num_rounds
        )
        # (num_rounds, num_envs) -> (E,) with the overshoot trimmed
        flat = lambda x: x.reshape((num_rounds * num_envs,))[:num_episodes]
        return EvalMetrics(
            episode_return=flat(team),
            agent_returns={a: flat(rets[a]) for a in ids},
            episode_length=flat(length),
        )

    return eval_fn


def evaluate(
    system,
    params,
    key,
    num_episodes: int = 32,
    num_envs: int = 16,
    num_seeds: int | None = None,
) -> EvalMetrics:
    """Standalone jit-compiled greedy evaluation.

    `params` may be a full TrainState or bare network params. Same
    (params, key) always produces bitwise-identical returns, and matches
    the interleaved evaluator built with the same (num_episodes, num_envs).

    With ``num_seeds`` set, ``params`` and ``key`` must both carry a leading
    ``(num_seeds,)`` axis (e.g. the train states out of seed-vectorized
    `train_anakin` plus stacked per-seed keys): all seeds evaluate in one
    vmapped jit program and every `EvalMetrics` leaf gains that axis.
    """
    eval_fn = make_evaluator(system, num_episodes, num_envs)
    if num_seeds is not None:
        def lane(x):
            return jnp.shape(x)[0] if jnp.ndim(x) else None  # None: unbatched
        lanes = {lane(leaf) for leaf in jax.tree_util.tree_leaves(params)}
        lanes.add(lane(key))
        if lanes != {num_seeds}:
            raise ValueError(
                f"num_seeds={num_seeds} but params/key carry leading axes "
                f"{sorted(lanes, key=str)}"
            )
        eval_fn = jax.vmap(eval_fn)
    return jax.jit(eval_fn)(params, key)
