"""Fused, statistically-robust evaluation (`repro.eval`).

  evaluator — jit/vmap greedy evaluator; standalone or interleaved in runners
  stats     — rliable-style aggregates (mean/median/IQM + bootstrap CIs)
  sweep     — one system x every registered env -> BENCH_eval.json
"""
from repro.eval.evaluator import evaluate, make_evaluator
from repro.eval.stats import (
    aggregate,
    iqm,
    mean,
    median,
    stratified_bootstrap_ci,
)
from repro.eval.sweep import evaluate_on_env, run_sweep, to_markdown

__all__ = [
    "evaluate",
    "make_evaluator",
    "aggregate",
    "iqm",
    "mean",
    "median",
    "stratified_bootstrap_ci",
    "evaluate_on_env",
    "run_sweep",
    "to_markdown",
]
