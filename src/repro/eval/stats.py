"""Statistically-robust aggregate metrics (rliable-style, Agarwal et al. 2021).

Point aggregates (mean, median, interquartile mean) plus stratified-bootstrap
confidence intervals over a ``(num_seeds, num_episodes)`` score matrix — the
"scientifically sound and statistically robust research" half of the Mava
pitch.  Pure numpy on the host: aggregation happens once per eval sweep, so
there is nothing to fuse, and host numpy keeps the bootstrap deterministic
and dtype-stable across accelerators.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np


def _as_matrix(scores) -> np.ndarray:
    """Coerce scores to (num_seeds, num_episodes); 1-D input is one seed."""
    x = np.asarray(scores, dtype=np.float64)
    if x.ndim == 1:
        x = x[None, :]
    if x.ndim != 2:
        raise ValueError(f"scores must be 1-D or 2-D, got shape {x.shape}")
    return x


def mean(scores) -> float:
    """Mean over the flattened score matrix."""
    return float(np.mean(_as_matrix(scores)))


def median(scores) -> float:
    """Median over the flattened score matrix."""
    return float(np.median(_as_matrix(scores)))


def iqm(scores) -> float:
    """Interquartile mean: mean of the middle 50% of all scores.

    Discards the bottom and top 25% (floor'd), falling back to the plain
    mean when fewer than 4 scores are available.
    """
    x = np.sort(_as_matrix(scores), axis=None)
    cut = int(np.floor(x.size * 0.25))
    return float(np.mean(x[cut : x.size - cut]))


def stratified_bootstrap_ci(
    scores,
    statistic: Callable[[np.ndarray], float] = iqm,
    num_resamples: int = 2000,
    confidence: float = 0.95,
    seed: int = 0,
) -> Tuple[float, float]:
    """Percentile bootstrap CI, stratified by seed.

    Each resample redraws episodes with replacement *within every seed row*
    (the stratification), recomputes ``statistic`` on the resampled matrix,
    and the CI is the central ``confidence`` mass of the resulting
    distribution.  Deterministic for a fixed ``seed``.
    """
    x = _as_matrix(scores)
    rng = np.random.default_rng(seed)
    n_seeds, n_eps = x.shape
    stats = np.empty(num_resamples)
    for i in range(num_resamples):
        idx = rng.integers(0, n_eps, size=(n_seeds, n_eps))
        stats[i] = statistic(np.take_along_axis(x, idx, axis=1))
    alpha = (1.0 - confidence) / 2.0
    lo, hi = np.quantile(stats, [alpha, 1.0 - alpha])
    return float(lo), float(hi)


def aggregate(
    scores,
    num_resamples: int = 2000,
    confidence: float = 0.95,
    seed: int = 0,
) -> Dict[str, object]:
    """The full rliable-style report for one (system, env) cell."""
    x = _as_matrix(scores)
    report: Dict[str, object] = {
        "mean": mean(x),
        "median": median(x),
        "iqm": iqm(x),
        "std": float(np.std(x)),
        "num_seeds": int(x.shape[0]),
        "num_episodes": int(x.shape[1]),
    }
    for name, stat in (("iqm", iqm), ("mean", mean)):
        lo, hi = stratified_bootstrap_ci(
            x, stat, num_resamples=num_resamples, confidence=confidence, seed=seed
        )
        report[f"{name}_ci{int(confidence * 100)}"] = [lo, hi]
    return report
