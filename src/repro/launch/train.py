"""LM training driver.

  PYTHONPATH=src python -m repro.launch.train --arch minitron-8b --smoke \
      --steps 50 --batch 8 --seq 128

On this CPU container only --smoke (reduced) configs actually run; the full
configs are exercised via the dry-run. The loop is the real thing either
way: synthetic token pipeline -> jit'd train_step (donated state) ->
checkpoint every --ckpt-every steps.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import SyntheticTokenDataset
from repro.launch.steps import make_train_step
from repro.models import model as M


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, required=True)
    p.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.active_param_count()/1e6:.1f}M")

    key = jax.random.key(args.seed)
    params = M.init_model(key, cfg)
    opt, train_step = make_train_step(cfg, args.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    ds = SyntheticTokenDataset(cfg.vocab, args.seq, args.batch, seed=args.seed)
    rng = np.random.default_rng(args.seed)

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        host = ds.sample(rng)
        batch = {
            "tokens": jnp.asarray(host["tokens"]),
            "labels": jnp.asarray(host["labels"]),
        }
        if cfg.arch_type == "vlm":
            V = cfg.vision_tokens
            batch["vision_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, V, cfg.d_model)), cfg.activation_dtype
            )
        if cfg.arch_type == "audio":
            K = cfg.num_codebooks
            toks = rng.integers(0, cfg.vocab, (args.batch, args.seq + 1, K)).astype(
                np.int32
            )
            batch = {
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} ({dt:.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1, params)
            print(f"  checkpoint -> {path}")

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} ({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
