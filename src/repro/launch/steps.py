"""Step factories + abstract input specs for the dry-run and real training.

For each (architecture, input shape) the dry-run lowers exactly one of:

  train_4k     -> train_step   (fwd + bwd + AdamW update)
  prefill_32k  -> prefill_step (full-prompt forward, returns decode cache)
  decode_32k   -> serve_step   (ONE token against a seq_len KV cache)
  long_500k    -> serve_step   (sub-quadratic variants; see shape_config)

input_specs() returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for every model input of that step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.distributed.sharding import logical_to_spec, rules_for, tree_shardings
from repro.models import model as M
from repro.models.config import InputShape, ModelConfig, get_input_shape


# ----------------------------------------------------------- config per shape


def shape_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt an arch config to an input shape.

    long_500k decode requires sub-quadratic attention: SSM/hybrid archs are
    natively O(1)/token; attention archs get their sliding-window variant
    (cfg.long_context_window) so the KV cache is O(window), not O(seq).
    """
    if shape.name == "long_500k" and cfg.arch_type != "ssm" and cfg.attn_window == 0:
        cfg = dataclasses.replace(cfg, attn_window=cfg.long_context_window)
    return cfg


# ------------------------------------------------------------- abstract trees


def abstract_params(cfg: ModelConfig, mesh=None):
    shapes = jax.eval_shape(functools.partial(M.init_model, cfg=cfg), jax.random.key(0))
    if mesh is None:
        return shapes, None
    axes = M.model_axes(cfg)
    shardings = tree_shardings(axes, mesh, rules_for(cfg.sharding), shapes)
    with_sharding = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes,
        shardings,
    )
    return with_sharding, shardings


def make_optimizer(cfg: ModelConfig, lr: float = 3e-4):
    return optim.chain(
        optim.clip_by_global_norm(1.0),
        optim.adamw(lr, weight_decay=0.1),
    )


def abstract_opt_state(cfg: ModelConfig, opt, params_abs, mesh=None):
    shapes = jax.eval_shape(opt.init, params_abs)
    if mesh is None:
        return shapes, None

    # optimizer state mirrors the param shardings elementwise; scalars and
    # empty tuples are replicated.
    def sharding_like(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return None

    params_flat = {
        tuple(str(p) for p in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(params_abs)[0]
    }

    def assign(path, leaf):
        if leaf.ndim == 0:
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, P()))
        # match the trailing path against a param leaf (mu/nu trees mirror params)
        for ppath, ps in params_flat.items():
            if leaf.shape == ps.shape and path[-len(ppath):] == ppath:
                return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ps.sharding)
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, P())
        )

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    leaves = [assign(tuple(str(p) for p in path), leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves), None


def batch_sharding(mesh, batch: Optional[int] = None):
    """Batch-dim sharding over (pod, data), dropping non-dividing axes."""
    shape = (batch,) if batch is not None else None
    spec = logical_to_spec(("batch",), rules_for("tp"), mesh, shape=shape)
    return NamedSharding(mesh, spec)


def input_specs(cfg: ModelConfig, shape: InputShape, mesh=None):
    """Abstract model inputs for the given step kind."""
    B, S = shape.global_batch, shape.seq_len
    bs = batch_sharding(mesh, B) if mesh is not None else None

    def tok(shp):
        return jax.ShapeDtypeStruct(shp, jnp.int32, sharding=bs)

    def emb(shp):
        return jax.ShapeDtypeStruct(shp, cfg.activation_dtype, sharding=bs)

    if shape.kind in ("train", "prefill"):
        if cfg.arch_type == "audio":
            batch = {"tokens": tok((B, S, cfg.num_codebooks))}
            if shape.kind == "train":
                batch["labels"] = tok((B, S, cfg.num_codebooks))
        elif cfg.arch_type == "vlm":
            T = S - cfg.vision_tokens
            batch = {
                "tokens": tok((B, T)),
                "vision_embeds": emb((B, cfg.vision_tokens, cfg.d_model)),
            }
            if shape.kind == "train":
                batch["labels"] = tok((B, T))
        else:
            batch = {"tokens": tok((B, S))}
            if shape.kind == "train":
                batch["labels"] = tok((B, S))
        return batch

    # decode: ONE new token + a cache of S tokens
    if cfg.arch_type == "audio":
        return {"tokens": tok((B, 1, cfg.num_codebooks))}
    return {"tokens": tok((B, 1))}


def abstract_cache(cfg: ModelConfig, shape: InputShape, mesh=None):
    """ShapeDtypeStructs for the decode cache (capacity = shape.seq_len)."""
    shapes = jax.eval_shape(
        functools.partial(M.init_cache, cfg, shape.global_batch, shape.seq_len)
    )
    if mesh is None:
        return shapes
    axes = M.cache_axes(cfg)
    rules = rules_for(cfg.sharding)

    def to_struct(s, ax):
        spec = logical_to_spec(ax, rules, mesh, shape=s.shape)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, spec))

    # shapes' leaves are ShapeDtypeStructs; the matching axes subtree (a tuple
    # of logical names) is passed whole to to_struct by flatten_up_to.
    return jax.tree_util.tree_map(to_struct, shapes, axes)


# ------------------------------------------------------------------ steps


def make_train_step(cfg: ModelConfig, lr: float = 3e-4):
    opt = make_optimizer(cfg, lr)
    k = max(cfg.grad_accum, 1)

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                M.forward_train, has_aux=True
            )(params, batch, cfg)
        else:
            # gradient accumulation over k microbatches: peak activation
            # memory drops to one microbatch; grads accumulate in fp32.
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
            )

            def micro_step(acc, mb):
                (loss, metrics), grads = jax.value_and_grad(
                    M.forward_train, has_aux=True
                )(params, mb, cfg)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / k, acc, grads
                )
                return acc, metrics

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, metrics_k = jax.lax.scan(micro_step, zero, micro)
            metrics = jax.tree_util.tree_map(jnp.mean, metrics_k)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, metrics

    return opt, train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        logits, cache = M.decode_step(params, cache, batch["tokens"], cfg)
        # greedy next token (serving returns tokens, not logits)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
