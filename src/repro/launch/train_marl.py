"""MARL system launcher — the JAX analogue of the paper's Block 2.

Where Acme-Mava built a Launchpad program graph
(madqn.MADQN(...).build(); launchpad.launch(program, LOCAL_MULTI_PROCESSING)),
here any system in ``repro.systems.REGISTRY`` is launched at three scales
by picking a runner:

  --runner loop     the paper's Block-1 python environment loop (faithful)
  --runner anakin   fused jit: scan(steps) x vmap(num_envs)
  --runner sharded  shard_map over the mesh data axis (num_executors devices)
  --runner async    IMPALA-style async actor/learner: --num-actors vmapped
                    actor replicas feed a device-resident trajectory queue,
                    the learner consumes with --param-sync-every bounded
                    staleness (see docs/DISTRIBUTED.md)

Action-space compatibility is spec-driven: each registry entry declares
discrete/continuous support and the env's spec is checked against it (a
continuous-control system automatically builds the env in continuous mode
when it has one).

Observability (``repro.obs``): ``--log-every N`` streams in-flight
metrics (iteration, update count, live SPS, episode return) out of the
fused jit every N iterations; ``--log-dir`` writes a structured run
record — config, provenance, compile-vs-steady timing, per-phase timing,
the metric stream as JSONL+CSV — under ``<log-dir>/<run-id>/``; and
``--profile`` captures a ``jax.profiler`` trace plus a `repro.roofline`
HLO-cost summary into the same record.  All human-facing output goes
through the `ConsoleSink`, so streamed telemetry and launcher reporting
share one formatting path.

  PYTHONPATH=src python -m repro.launch.train_marl --system ippo \
      --env smax_lite --runner anakin --iterations 5000 --num-envs 16 \
      --log-every 500 --log-dir results/runs --profile
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.core.system import (
    make_anakin,
    run_environment_loop,
    train_distributed,
)
from repro.distributed.impala import default_unroll_len, train_async
from repro.envs import REGISTRY as ENVS
from repro.obs import (
    ConsoleSink,
    CsvSink,
    JsonlSink,
    MetricTap,
    MultiLogger,
    RetraceCounter,
    RunRecord,
    SeedAggregator,
    measure_phase_timing,
    profile_trace,
    roofline_summary,
)
from repro.systems.registry import REGISTRY as SYSTEMS
from repro.systems.registry import make_pair


def parse_args(argv=None):
    """The launcher CLI (exposed for the telemetry smoke tests)."""
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=sorted(SYSTEMS), default="madqn")
    p.add_argument("--env", choices=sorted(ENVS), default="smax_lite")
    p.add_argument(
        "--runner", choices=("loop", "anakin", "sharded", "async"),
        default="anakin",
    )
    p.add_argument("--iterations", type=int, default=2000)
    p.add_argument("--num-envs", type=int, default=16)
    p.add_argument("--num-executors", type=int, default=2, help="devices (sharded)")
    p.add_argument(
        "--num-actors", type=int, default=2,
        help="async: actor replicas feeding the trajectory queue "
        "(--iterations counts env steps per env per actor and must divide "
        "into the system's unroll length)",
    )
    p.add_argument(
        "--param-sync-every", type=int, default=1,
        help="async: refresh the actors' param snapshot every N learner "
        "ticks (1 = every tick; staleness stays < N)",
    )
    p.add_argument(
        "--num-seeds", type=int, default=0,
        help="anakin: train N independent seeds as one vmapped jit "
        "(0 = a single run); streamed metrics aggregate over lanes",
    )
    p.add_argument(
        "--continuous", action="store_true",
        help="force the env's continuous-action mode (spec-checked; "
        "continuous systems enable it automatically)",
    )
    p.add_argument("--seed", type=int, default=0)
    p.add_argument(
        "--eval-every", type=int, default=0,
        help="anakin: run the fused greedy evaluator inside the training jit "
        "every N iterations (0 = off); sharded: any value > 0 evaluates the "
        "final params on every device",
    )
    p.add_argument("--eval-episodes", type=int, default=32)
    p.add_argument(
        "--log-every", type=int, default=0,
        help="stream in-flight metrics from inside the fused jit every N "
        "iterations (0 = off); a pure observer — results are bitwise "
        "identical with it on or off",
    )
    p.add_argument(
        "--log-dir", default=None,
        help="write a structured run record (run.json + metrics.jsonl/csv) "
        "under <log-dir>/<run-id>/ — see docs/OBSERVABILITY.md",
    )
    p.add_argument(
        "--run-id", default=None,
        help="run-record directory name (default: a generated sortable id)",
    )
    p.add_argument(
        "--profile", action="store_true",
        help="capture a jax.profiler trace directory and attach a "
        "repro.roofline HLO-cost summary to the run record",
    )
    p.add_argument(
        "--save-checkpoint", default=None, metavar="DIR",
        help="write the final trained policy as a self-describing "
        "checkpoint directory (system/env/config + params via "
        "repro.checkpoint; per-seed lanes when --num-seeds > 1) that "
        "repro.serve can restore — see docs/SERVING.md",
    )
    return p.parse_args(argv)


def run(args) -> None:
    """Launch one training run as configured (the CLI body)."""
    console = ConsoleSink()
    record = None
    logger = console
    if args.log_dir:
        record = RunRecord(
            args.log_dir, run_id=args.run_id, config=vars(args),
            tag=f"{args.system}-{args.env}",
        )
        logger = MultiLogger(
            console,
            JsonlSink(record.metrics_path("jsonl")),
            CsvSink(record.metrics_path("csv")),
        )
        console.line(f"run record: {record.dir}")

    env_kwargs = {"continuous": True} if args.continuous else None
    axis = "data" if args.runner == "sharded" else None
    env, system = make_pair(
        args.system, args.env, distributed_axis=axis, env_kwargs=env_kwargs
    )
    key = jax.random.key(args.seed)
    num_seeds = args.num_seeds if args.num_seeds > 0 else None

    tap = None
    if args.log_every > 0 and args.runner != "loop":
        stream_logger = SeedAggregator(logger) if num_seeds else logger
        # the async runner's scan unit is one learner tick (= unroll_len
        # acting steps on each of num_actors replicas), not one env step
        steps_per_iteration = (
            default_unroll_len(system) * args.num_envs * args.num_actors
            if args.runner == "async"
            else args.num_envs * (num_seeds or 1)
        )
        tap = MetricTap(
            stream_logger, args.log_every,
            steps_per_iteration=steps_per_iteration,
        )

    trace_ctx = contextlib.nullcontext({})
    if args.profile:
        trace_root = record.dir if record is not None else "results"
        trace_ctx = profile_trace(f"{trace_root}/trace")

    program = None
    final_metrics = {}
    with RetraceCounter() as rc:
        t0 = time.perf_counter()
        with trace_ctx as trace_info:
            final_train = None  # the trained policy --save-checkpoint persists
            if args.runner == "loop":
                final_train, _, ev = run_environment_loop(
                    system, key, num_episodes=args.iterations
                )
                returns = ev.episode_return
                final_metrics = {
                    "first_returns": float(np.mean(returns[:3])),
                    "last_returns": float(np.mean(returns[-3:])),
                }
                console.write(
                    {"episode_return_first": final_metrics["first_returns"],
                     "episode_return_last": final_metrics["last_returns"]}
                )
            elif args.runner == "anakin":
                program = make_anakin(
                    system, args.iterations, args.num_envs,
                    eval_every=args.eval_every,
                    eval_episodes=args.eval_episodes,
                    num_seeds=num_seeds,
                    log_every=args.log_every,
                    log_callback=tap,
                )
                if tap is not None:
                    tap.reset_clock()
                out = jax.block_until_ready(program(key))
                if tap is not None:
                    # debug.callback is async: drain the queue so the tap's
                    # emit count (and the sinks) reflect the whole run
                    jax.effects_barrier()
                if args.eval_every > 0:
                    st, metrics, evals = out
                    ev_returns = np.asarray(evals.episode_return).mean(axis=-1)
                    console.line(
                        "greedy eval return (team), per eval point: "
                        + np.array2string(ev_returns, precision=3)
                    )
                    final_metrics["eval_returns"] = ev_returns.tolist()
                else:
                    st, metrics = out
                final_train = st.train
                r = np.asarray(metrics["reward"])
                k = max(r.shape[-1] // 10, 1)
                final_metrics["reward_first10pct"] = float(r[..., :k].mean())
                final_metrics["reward_last10pct"] = float(r[..., -k:].mean())
                console.write(
                    {"reward_first10pct": final_metrics["reward_first10pct"],
                     "reward_last10pct": final_metrics["reward_last10pct"]}
                )
            elif args.runner == "async":
                if tap is not None:
                    tap.reset_clock()
                # inside the runner log_every counts learner ticks (the async
                # scan unit), but the CLI flag is denominated in iterations
                # like every other runner: convert, emitting at least as
                # often as one tap per run
                log_every_ticks = (
                    max(1, args.log_every // default_unroll_len(system))
                    if args.log_every > 0
                    else 0
                )
                st, metrics = train_async(
                    system, key, args.iterations, args.num_envs,
                    args.num_actors,
                    param_sync_every=args.param_sync_every,
                    log_every=log_every_ticks,
                    log_callback=tap,
                )
                final_train = st.train
                r = np.asarray(metrics["reward"])
                k = max(r.shape[-1] // 10, 1)
                final_metrics["reward_first10pct"] = float(r[..., :k].mean())
                final_metrics["reward_last10pct"] = float(r[..., -k:].mean())
                # the async runner's own telemetry: queue pressure and the
                # actual staleness of what the learner consumed
                final_metrics["num_actors"] = args.num_actors
                final_metrics["param_sync_every"] = args.param_sync_every
                final_metrics["queue_depth_mean"] = float(
                    np.mean(metrics["queue_depth"])
                )
                final_metrics["staleness_mean"] = float(
                    np.mean(metrics["staleness"])
                )
                final_metrics["dropped_chunks"] = float(metrics["dropped"][-1])
                console.write(
                    {"reward_first10pct": final_metrics["reward_first10pct"],
                     "reward_last10pct": final_metrics["reward_last10pct"],
                     "queue_depth_mean": final_metrics["queue_depth_mean"],
                     "staleness_mean": final_metrics["staleness_mean"],
                     "dropped_chunks": final_metrics["dropped_chunks"]}
                )
            else:
                from repro.launch.mesh import make_auto_mesh

                mesh = make_auto_mesh((args.num_executors,), ("data",))
                out = train_distributed(
                    system, key, args.iterations, args.num_envs, mesh,
                    eval_episodes=(
                        args.eval_episodes if args.eval_every > 0 else 0
                    ),
                    log_every=args.log_every,
                    log_callback=tap,
                )
                params, metrics = out[0], out[1]
                # the sharded runner returns bare replicated params; they
                # save as a params-only checkpoint (servable, not resumable)
                final_train = params
                rewards = np.asarray(metrics["reward"]).ravel()
                console.write(
                    {"per_executor_reward": rewards.tolist()}
                )
                final_metrics["per_executor_reward"] = rewards.tolist()
                if args.eval_every > 0:
                    ev = np.asarray(out[2]).ravel()
                    console.write({"per_executor_eval_return": ev.tolist()})
                    final_metrics["per_executor_eval_return"] = ev.tolist()
        wall = time.perf_counter() - t0

    if args.runner == "async":
        # wall-clock throughput split per actor replica (compile included;
        # the BENCH_speed async_actors rung reports the steady-state number)
        total_steps = args.iterations * args.num_envs * args.num_actors
        final_metrics["steps_per_sec"] = total_steps / wall
        final_metrics["per_actor_steps_per_sec"] = (
            total_steps / wall / args.num_actors
        )
    console.line(
        f"wall time: {wall:.1f}s  "
        f"({args.system} on {args.env}, runner={args.runner})"
    )
    if args.save_checkpoint:
        from repro.serve.checkpoint import save_policy

        meta_path = save_policy(
            args.save_checkpoint,
            args.system,
            args.env,
            final_train,
            env_kwargs=env_kwargs,
            num_seeds=num_seeds,
            step=args.iterations,
        )
        console.line(f"wrote policy checkpoint: {meta_path}")
    if args.log_every > 0 and tap is not None:
        console.line(f"streamed {tap.emits} in-flight telemetry rows")

    if record is not None:
        retrace = rc.summary()
        record.update("retrace", **retrace)
        record.update(
            "timing",
            total_seconds=wall,
            compile_seconds=retrace["compile_seconds"],
            steady_seconds=max(wall - retrace["compile_seconds"], 0.0),
        )
        record.update(
            "timing",
            phases=measure_phase_timing(
                system, args.num_envs, jax.random.key(args.seed),
                eval_episodes=(
                    args.eval_episodes if args.eval_every > 0 else 0
                ),
            ),
        )
        record.update("metrics", **final_metrics)
        if args.profile:
            record.update("profile", **trace_info)
            if program is not None:
                # AOT-lower the fused program for the trip-count-aware
                # HLO-cost block (an extra backend compile, --profile only)
                compiled = program.fused.lower(program.init_fn(key)).compile()
                record.update(
                    "profile", roofline=roofline_summary(compiled.as_text())
                )
        path = record.save()
        console.line(f"wrote run record: {path}")
    logger.close()


def main():
    run(parse_args())


if __name__ == "__main__":
    main()
