"""MARL system launcher — the JAX analogue of the paper's Block 2.

Where Acme-Mava built a Launchpad program graph
(madqn.MADQN(...).build(); launchpad.launch(program, LOCAL_MULTI_PROCESSING)),
here any system in ``repro.systems.REGISTRY`` is launched at three scales
by picking a runner:

  --runner loop     the paper's Block-1 python environment loop (faithful)
  --runner anakin   fused jit: scan(steps) x vmap(num_envs)
  --runner sharded  shard_map over the mesh data axis (num_executors devices)

Action-space compatibility is spec-driven: each registry entry declares
discrete/continuous support and the env's spec is checked against it (a
continuous-control system automatically builds the env in continuous mode
when it has one).

  PYTHONPATH=src python -m repro.launch.train_marl --system ippo \
      --env smax_lite --runner anakin --iterations 5000 --num-envs 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.system import (
    run_environment_loop,
    train_anakin,
    train_distributed,
)
from repro.envs import REGISTRY as ENVS
from repro.systems.registry import REGISTRY as SYSTEMS
from repro.systems.registry import make_pair


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=sorted(SYSTEMS), default="madqn")
    p.add_argument("--env", choices=sorted(ENVS), default="smax_lite")
    p.add_argument("--runner", choices=("loop", "anakin", "sharded"), default="anakin")
    p.add_argument("--iterations", type=int, default=2000)
    p.add_argument("--num-envs", type=int, default=16)
    p.add_argument("--num-executors", type=int, default=2, help="devices (sharded)")
    p.add_argument(
        "--continuous", action="store_true",
        help="force the env's continuous-action mode (spec-checked; "
        "continuous systems enable it automatically)",
    )
    p.add_argument("--seed", type=int, default=0)
    p.add_argument(
        "--eval-every", type=int, default=0,
        help="anakin: run the fused greedy evaluator inside the training jit "
        "every N iterations (0 = off); sharded: any value > 0 evaluates the "
        "final params on every device",
    )
    p.add_argument("--eval-episodes", type=int, default=32)
    args = p.parse_args()

    env_kwargs = {"continuous": True} if args.continuous else None
    axis = "data" if args.runner == "sharded" else None
    env, system = make_pair(
        args.system, args.env, distributed_axis=axis, env_kwargs=env_kwargs
    )
    key = jax.random.key(args.seed)

    t0 = time.time()
    if args.runner == "loop":
        _, _, ev = run_environment_loop(system, key, num_episodes=args.iterations)
        returns = ev.episode_return
        print(f"episode returns (team): first={np.mean(returns[:3]):.2f} "
              f"last={np.mean(returns[-3:]):.2f}")
    elif args.runner == "anakin":
        if args.eval_every > 0:
            st, metrics, evals = train_anakin(
                system, key, args.iterations, args.num_envs,
                eval_every=args.eval_every, eval_episodes=args.eval_episodes,
            )
            ev_returns = np.asarray(evals.episode_return).mean(axis=-1)
            print("greedy eval return (team), per eval point:",
                  np.array2string(ev_returns, precision=3))
        else:
            st, metrics = train_anakin(system, key, args.iterations, args.num_envs)
        r = np.asarray(metrics["reward"])
        k = max(len(r) // 10, 1)
        print(f"reward/step: first-10%={r[:k].mean():.3f} last-10%={r[-k:].mean():.3f}")
    else:
        from repro.launch.mesh import make_auto_mesh

        mesh = make_auto_mesh((args.num_executors,), ("data",))
        out = train_distributed(
            system, key, args.iterations, args.num_envs, mesh,
            eval_episodes=args.eval_episodes if args.eval_every > 0 else 0,
        )
        params, metrics = out[0], out[1]
        print("per-executor reward:", np.asarray(metrics["reward"]).ravel())
        if args.eval_every > 0:
            print("per-executor greedy eval return:", np.asarray(out[2]).ravel())
    print(f"wall time: {time.time() - t0:.1f}s  "
          f"({args.system} on {args.env}, runner={args.runner})")


if __name__ == "__main__":
    main()
