"""MARL system launcher — the JAX analogue of the paper's Block 2.

Where Acme-Mava built a Launchpad program graph
(madqn.MADQN(...).build(); launchpad.launch(program, LOCAL_MULTI_PROCESSING)),
here the *same system definition* is launched at three scales by picking a
runner:

  --runner loop     the paper's Block-1 python environment loop (faithful)
  --runner anakin   fused jit: scan(steps) x vmap(num_envs)
  --runner sharded  shard_map over the mesh data axis (num_executors devices)

  PYTHONPATH=src python -m repro.launch.train_marl --system vdn \
      --env smax_lite --runner anakin --iterations 5000 --num-envs 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.system import (
    run_environment_loop,
    train_anakin,
    train_distributed,
)
from repro.envs import REGISTRY as ENVS
from repro.systems.madqn import make_madqn
from repro.systems.maddpg import MaddpgConfig, make_mad4pg, make_maddpg
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.qmix import make_qmix
from repro.systems.vdn import make_vdn

SYSTEMS = {
    "madqn": lambda env, axis: make_madqn(env, OffPolicyConfig(distributed_axis=axis)),
    "madqn-fp": lambda env, axis: make_madqn(
        env, OffPolicyConfig(distributed_axis=axis, fingerprint=True)
    ),
    "vdn": lambda env, axis: make_vdn(env, OffPolicyConfig(distributed_axis=axis)),
    "qmix": lambda env, axis: make_qmix(env, OffPolicyConfig(distributed_axis=axis)),
    "maddpg": lambda env, axis: make_maddpg(env, MaddpgConfig(distributed_axis=axis)),
    "mad4pg": lambda env, axis: make_mad4pg(env, MaddpgConfig(distributed_axis=axis)),
}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=sorted(SYSTEMS), default="madqn")
    p.add_argument("--env", choices=sorted(ENVS), default="smax_lite")
    p.add_argument("--runner", choices=("loop", "anakin", "sharded"), default="anakin")
    p.add_argument("--iterations", type=int, default=2000)
    p.add_argument("--num-envs", type=int, default=16)
    p.add_argument("--num-executors", type=int, default=2, help="devices (sharded)")
    p.add_argument("--continuous", action="store_true", help="continuous actions (spread)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    env_kwargs = {}
    if args.env == "spread" and (args.continuous or "ddpg" in args.system or "d4pg" in args.system):
        env_kwargs["continuous"] = True
    env = ENVS[args.env](**env_kwargs)
    axis = "data" if args.runner == "sharded" else None
    system = SYSTEMS[args.system](env, axis)
    key = jax.random.key(args.seed)

    t0 = time.time()
    if args.runner == "loop":
        _, _, returns = run_environment_loop(system, key, num_episodes=args.iterations)
        print(f"episode returns: first={np.mean(returns[:3]):.2f} "
              f"last={np.mean(returns[-3:]):.2f}")
    elif args.runner == "anakin":
        st, metrics = train_anakin(system, key, args.iterations, args.num_envs)
        r = np.asarray(metrics["reward"])
        k = max(len(r) // 10, 1)
        print(f"reward/step: first-10%={r[:k].mean():.3f} last-10%={r[-k:].mean():.3f}")
    else:
        mesh = jax.make_mesh(
            (args.num_executors,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,),
        )
        params, metrics = train_distributed(
            system, key, args.iterations, args.num_envs, mesh
        )
        print("per-executor reward:", np.asarray(metrics["reward"]).ravel())
    print(f"wall time: {time.time() - t0:.1f}s  "
          f"({args.system} on {args.env}, runner={args.runner})")


if __name__ == "__main__":
    main()
