"""Production mesh construction (TPU v5e pods).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)           # 256 chips
MULTI_POD_SHAPE = (2, 16, 16)         # 2 pods x 256 chips


def make_auto_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types across jax versions.

    Newer jax wants explicit axis_types; on releases without
    `jax.sharding.AxisType` Auto is already the (only) default.
    """
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
