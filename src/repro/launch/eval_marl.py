"""Scenario-sweep evaluation launcher — the measurement half of Block 2.

Runs one registered system across every env in `repro.envs.REGISTRY` (or a
single named env) with the fused greedy evaluator, and writes the
``BENCH_eval.json`` artifact: per-env returns over seeds x episodes, robust
aggregates (IQM + stratified-bootstrap 95% CI), and eval steps/sec.

  PYTHONPATH=src python -m repro.launch.eval_marl --system vdn --env all
  PYTHONPATH=src python -m repro.launch.eval_marl --system qmix \
      --env smax_lite --train-iterations 2000 --seeds 0 1 2
"""
from __future__ import annotations

import argparse

from repro.envs import REGISTRY as ENVS
from repro.eval.sweep import run_sweep
from repro.launch.train_marl import SYSTEMS


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=sorted(SYSTEMS), default="vdn")
    p.add_argument(
        "--env", choices=sorted(ENVS) + ["all"], default="all",
        help="one registered env, or 'all' for the full registry sweep",
    )
    p.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    p.add_argument("--eval-episodes", type=int, default=32)
    p.add_argument("--num-envs", type=int, default=16, help="parallel eval envs")
    p.add_argument(
        "--train-iterations", type=int, default=0,
        help="anakin training iterations per seed before eval (0 = eval "
        "freshly-initialised params; useful for throughput/pipeline checks)",
    )
    p.add_argument("--out", default="BENCH_eval.json")
    args = p.parse_args()

    env_names = sorted(ENVS) if args.env == "all" else [args.env]
    make_system = lambda env: SYSTEMS[args.system](env, None)
    run_sweep(
        args.system,
        make_system,
        env_names=env_names,
        seeds=args.seeds,
        num_episodes=args.eval_episodes,
        num_envs=args.num_envs,
        train_iterations=args.train_iterations,
        out_path=args.out,
    )


if __name__ == "__main__":
    main()
