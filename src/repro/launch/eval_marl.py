"""Scenario-sweep evaluation launcher — the measurement half of Block 2.

Runs any set of registered systems across any set of registered envs with
the fused greedy evaluator, and writes the ``BENCH_eval.json`` artifact:
every (system, env) cell of the support matrix, with per-cell returns over
seeds x episodes, robust aggregates (IQM + stratified-bootstrap 95% CI)
and eval steps/sec for runnable cells, and the spec-driven incompatibility
reason for the rest.

  # the full system x env compatibility matrix
  PYTHONPATH=src python -m repro.launch.eval_marl

  # a focused slice, with training before eval
  PYTHONPATH=src python -m repro.launch.eval_marl --systems qmix ippo \
      --envs smax_lite --train-iterations 2000 --seeds 0 1 2
"""
from __future__ import annotations

import argparse
import time

from repro.envs import REGISTRY as ENVS
from repro.eval.sweep import run_sweep
from repro.obs import ConsoleSink
from repro.systems.registry import REGISTRY as SYSTEMS


def main():
    p = argparse.ArgumentParser()
    p.add_argument(
        "--systems", nargs="+", choices=sorted(SYSTEMS) + ["all"],
        default=["all"],
        help="registered systems to sweep, or 'all' for the full registry",
    )
    p.add_argument(
        "--envs", nargs="+", choices=sorted(ENVS) + ["all"], default=["all"],
        help="registered envs to sweep, or 'all' for the full registry",
    )
    p.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    p.add_argument("--eval-episodes", type=int, default=32)
    p.add_argument("--num-envs", type=int, default=16, help="parallel eval envs")
    p.add_argument(
        "--train-iterations", type=int, default=0,
        help="anakin training iterations per seed before eval (0 = eval "
        "freshly-initialised params; useful for throughput/pipeline checks)",
    )
    p.add_argument("--out", default="BENCH_eval.json")
    args = p.parse_args()

    system_names = sorted(SYSTEMS) if "all" in args.systems else args.systems
    env_names = sorted(ENVS) if "all" in args.envs else args.envs
    # all human-facing output (per-cell lines inside run_sweep and the
    # closing summary here) flows through the one ConsoleSink path
    console = ConsoleSink()
    t0 = time.perf_counter()
    run_sweep(
        system_names=system_names,
        env_names=env_names,
        seeds=args.seeds,
        num_episodes=args.eval_episodes,
        num_envs=args.num_envs,
        train_iterations=args.train_iterations,
        out_path=args.out,
    )
    console.line(
        f"swept {len(system_names)} system(s) x {len(env_names)} env(s) in "
        f"{time.perf_counter() - t0:.1f}s"
    )


if __name__ == "__main__":
    main()
