"""Batched serving driver: prefill a batch of prompts, then decode tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.key(args.seed)
    params = M.init_model(key, cfg)
    rng = np.random.default_rng(args.seed)

    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    if cfg.arch_type == "audio":
        prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S, cfg.num_codebooks)), jnp.int32)}
    elif cfg.arch_type == "vlm":
        V = cfg.vision_tokens
        prompt = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - V)), jnp.int32),
            "vision_embeds": jnp.asarray(rng.normal(size=(B, V, cfg.d_model)), cfg.activation_dtype),
        }
    else:
        prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}

    prefill_fn = jax.jit(lambda p_, b: M.prefill(p_, b, cfg, max_len=max_len))
    decode_fn = jax.jit(lambda p_, c, t: M.decode_step(p_, c, t, cfg))

    t0 = time.time()
    logits, cache = prefill_fn(params, prompt)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{S} in {t_prefill*1e3:.1f}ms")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B,1) or (B,1,K)
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode_fn(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = np.concatenate(out, axis=1)
    print(f"decode: {args.gen} tokens x {B} streams in {dt*1e3:.1f}ms "
          f"({args.gen * B / max(dt, 1e-9):.0f} tok/s)")
    n_show = min(16, toks.shape[1])
    print("sample stream 0:", np.asarray(toks[0, :n_show]).squeeze().tolist())


if __name__ == "__main__":
    main()
