import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh without allocating a single parameter.

  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

For each pair this builds abstract (ShapeDtypeStruct) params / optimizer
state / batch / cache with their NamedShardings, jits the right step with
explicit in/out shardings, lowers, compiles, and reports
memory_analysis() (fits-per-device proof) + cost_analysis() + the
collective schedule (for EXPERIMENTS.md §Dry-run / §Roofline).
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh, num_chips
from repro.launch.steps import (
    abstract_cache,
    abstract_opt_state,
    abstract_params,
    batch_sharding,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    shape_config,
)
from repro.models.config import INPUT_SHAPES, get_input_shape
from repro.models.model import model_flops_per_token
from repro.roofline.analysis import roofline_terms


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


def dryrun_pair(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    verbose=True,
    overrides: dict | None = None,
):
    """Lower+compile one (arch, shape). Returns a result-record dict.

    `overrides` replaces ModelConfig fields (the §Perf hillclimb hook), e.g.
    {"grad_accum": 8, "sharding": "fsdp_tp_sp"}.
    """
    import dataclasses as _dc

    from repro.distributed.sharding import enter_mesh, set_active_rules

    cfg = shape_config(get_config(arch), get_input_shape(shape_name))
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = get_input_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)

    t0 = time.time()
    params_abs, _ = abstract_params(cfg, mesh)
    batch_abs = input_specs(cfg, shape, mesh)
    rep = NamedSharding(mesh, P())

    with enter_mesh(mesh), set_active_rules(cfg.sharding):
        if shape.kind == "train":
            opt, train_step = make_train_step(cfg)
            opt_abs, _ = abstract_opt_state(cfg, opt, params_abs, mesh)
            shardings = lambda tree: jax.tree_util.tree_map(
                lambda x: x.sharding, tree
            )
            step = jax.jit(
                train_step,
                in_shardings=(shardings(params_abs), shardings(opt_abs),
                              shardings(batch_abs)),
                out_shardings=(shardings(params_abs), shardings(opt_abs), rep),
                donate_argnums=(0, 1),
            )
            lowered = step.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            prefill_step = make_prefill_step(cfg)
            step = jax.jit(prefill_step)
            lowered = step.lower(params_abs, batch_abs)
        else:  # decode
            serve_step = make_serve_step(cfg)
            cache_abs = abstract_cache(cfg, shape, mesh)
            step = jax.jit(serve_step, donate_argnums=(1,))
            lowered = step.lower(params_abs, cache_abs, batch_abs)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()

    # tokens processed per step (for model-flops). model_flops_per_token is
    # 6*N_active (fwd 2N + bwd 4N); forward-only steps use the 2N third.
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops_factor = 1.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops_factor = 1.0 / 3.0
    else:
        tokens = shape.global_batch
        flops_factor = 1.0 / 3.0
    model_flops = model_flops_per_token(cfg) * tokens * flops_factor

    report = roofline_terms(arch, shape_name, chips, cost, hlo, model_flops)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost},
        "roofline": report.row(),
    }
    if verbose:
        bpd = rec["bytes_per_device"]
        r = rec["roofline"]
        print(
            f"[OK] {arch:24s} {shape_name:12s} mesh={rec['mesh']:9s} "
            f"compile={rec['compile_s']:6.1f}s "
            f"peak/dev={bpd['peak_est']/2**30:7.2f}GiB "
            f"compute={r['compute_s']*1e3:9.3f}ms "
            f"memory={r['memory_s']*1e3:9.3f}ms "
            f"coll={r['collective_s']*1e3:9.3f}ms "
            f"dom={r['dominant']:10s} useful={r['useful_ratio']:5.2f}"
        )
        sys.stdout.flush()
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=[s.name for s in INPUT_SHAPES])
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--json", default=None)
    args = p.parse_args()

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s.name))
    else:
        if not (args.arch and args.shape):
            p.error("need --arch and --shape, or --all")
        pairs = [(args.arch, args.shape)]

    records, failures = [], []
    for a, s in pairs:
        try:
            records.append(dryrun_pair(a, s, multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001 — report every failure at the end
            failures.append((a, s, f"{type(e).__name__}: {e}"))
            print(f"[FAIL] {a} {s}: {type(e).__name__}: {str(e)[:200]}")
            sys.stdout.flush()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
    print(f"\n{len(records)} ok, {len(failures)} failed")
    if failures:
        for a, s, err in failures:
            print(f"  FAIL {a} {s}: {err[:300]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
