"""MARL decision-serving launcher — the traffic half of the north star.

Serves restored policy checkpoints (any REGISTRY system, feed-forward or
recurrent) behind the `repro.serve.DecisionEngine` slot pool against
reproducible synthetic traffic — Poisson episode arrivals over N
concurrent user streams — and writes the ``BENCH_serve.json`` +
``BENCH_serve.md`` latency/throughput artifact (schema in docs/BENCH.md,
validated by ``scripts/check_bench_schema.py``): p50/p99 per-decision
latency and decisions/sec at every requested slot count.

Two ways in:

  # serve checkpoints you already trained (e.g. train_marl --save-checkpoint)
  PYTHONPATH=src python -m repro.launch.serve_marl \
      --checkpoints results/ckpts/ippo-matrix_game --slots 2 8

  # or train-then-serve: tiny anakin runs, each saved + *restored* before
  # serving, so the artifact always measures the checkpoint round trip
  PYTHONPATH=src python -m repro.launch.serve_marl \
      --systems ippo rec_ippo --env matrix_game --train-iterations 512 \
      --slots 2 8 --streams 8
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.envs import REGISTRY as ENVS
from repro.obs import ConsoleSink, provenance
from repro.serve import (
    DecisionEngine,
    load_policy,
    poisson_requests,
    read_policy_meta,
    save_policy,
    serve_workload,
)
from repro.systems.registry import REGISTRY as SYSTEMS


def parse_args(argv=None):
    """The serving CLI (exposed for the smoke tests)."""
    p = argparse.ArgumentParser()
    p.add_argument(
        "--checkpoints", nargs="+", default=None, metavar="DIR",
        help="policy checkpoint directories to serve (default: train tiny "
        "checkpoints for --systems on --env first)",
    )
    p.add_argument(
        "--systems", nargs="+", choices=sorted(SYSTEMS),
        default=["ippo", "rec_ippo"],
        help="systems to train-then-serve when no --checkpoints are given "
        "(default: the ff + recurrent on-policy pair)",
    )
    p.add_argument("--env", choices=sorted(ENVS), default="matrix_game")
    p.add_argument(
        "--train-iterations", type=int, default=512,
        help="anakin iterations for the train-then-serve checkpoints",
    )
    p.add_argument("--train-num-envs", type=int, default=8)
    p.add_argument(
        "--ckpt-dir", default="results/ckpts",
        help="where train-then-serve writes its checkpoints",
    )
    p.add_argument(
        "--slots", type=int, nargs="+", default=[2, 8],
        help="slot-pool sizes to serve at (one BENCH_serve cell each)",
    )
    p.add_argument(
        "--streams", type=int, default=8,
        help="concurrent user streams generating Poisson episode arrivals",
    )
    p.add_argument("--episodes-per-stream", type=int, default=4)
    p.add_argument(
        "--arrival-rate", type=float, default=0.2,
        help="episode requests per tick per stream (exponential gaps)",
    )
    p.add_argument("--mode", choices=("greedy", "sample"), default="greedy")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="BENCH_serve.json")
    return p.parse_args(argv)


def _train_checkpoints(args, console) -> list:
    """Train tiny anakin runs and persist them as policy checkpoints."""
    import jax

    from repro.bench.throughput import smoke_overrides
    from repro.core.system import train_anakin
    from repro.systems.registry import make_pair

    dirs = []
    for name in args.systems:
        overrides = smoke_overrides(name)
        _, system = make_pair(name, args.env, **overrides)
        st, _ = train_anakin(
            system, jax.random.key(args.seed),
            args.train_iterations, args.train_num_envs,
        )
        directory = str(pathlib.Path(args.ckpt_dir) / f"{name}-{args.env}")
        save_policy(
            directory, name, args.env, st.train,
            config_overrides=overrides, step=args.train_iterations,
        )
        console.line(f"trained + saved checkpoint: {directory}")
        dirs.append(directory)
    return dirs


def serve_cell(directory: str, max_slots: int, args) -> dict:
    """One BENCH_serve cell: a restored checkpoint under one slot count."""
    env, system, train = load_policy(directory)
    del env  # the engine serves system.env
    engine = DecisionEngine(
        system, train, max_slots=max_slots, mode=args.mode, seed=args.seed
    )
    requests = poisson_requests(
        args.streams, args.episodes_per_stream, args.arrival_rate,
        seed=args.seed,
    )
    stats = serve_workload(engine, requests)
    return {"checkpoint": directory, "max_slots": max_slots, **stats}


def run(args) -> dict:
    """Serve every checkpoint at every slot count; write the artifact."""
    console = ConsoleSink()
    if args.checkpoints is None:
        dirs = _train_checkpoints(args, console)
    else:
        dirs = list(args.checkpoints)

    results = {
        "workload": "serve",
        "provenance": provenance(),
        "config": {
            "streams": args.streams,
            "episodes_per_stream": args.episodes_per_stream,
            "arrival_rate": args.arrival_rate,
            "mode": args.mode,
            "seed": args.seed,
            "train_iterations": (
                args.train_iterations if args.checkpoints is None else 0
            ),
        },
        "cells": [],
    }
    for directory in dirs:
        meta = read_policy_meta(directory)
        for max_slots in args.slots:
            cell = serve_cell(directory, max_slots, args)
            cell["system"] = meta["system"]
            cell["env"] = meta["env"]
            results["cells"].append(cell)
            lat = cell["latency"]
            console.line(
                f"{cell['system']:>10s} x {cell['env']:<14s} "
                f"slots={max_slots:<3d}: "
                f"p50={lat['p50_ms']:.2f}ms p99={lat['p99_ms']:.2f}ms  "
                f"{cell['decisions_per_sec']:,.0f} decisions/s  "
                f"({cell['episodes']} episodes, "
                f"mean return {cell['episode_return_mean']:.3f})"
            )

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    md_path = str(pathlib.Path(args.out).with_suffix(".md"))
    with open(md_path, "w") as f:
        f.write(to_markdown(results))
    console.line(f"wrote {args.out} and {md_path}")
    return results


def to_markdown(results: dict) -> str:
    """Render the serving sweep as one row per (checkpoint, slot count)."""
    cfg = results["config"]
    lines = [
        "# Decision-serving latency/throughput — slot pool x checkpoint",
        "",
        f"{cfg['streams']} concurrent streams x "
        f"{cfg['episodes_per_stream']} episodes each, Poisson arrivals at "
        f"{cfg['arrival_rate']} req/tick/stream, mode={cfg['mode']}. "
        "Latency is per decision (one jitted tick advances every live "
        "slot); decisions/sec counts joint actions served.",
        "",
        "| system | env | slots | p50 (ms) | p99 (ms) | decisions/s | "
        "episodes | mean return |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cell in results["cells"]:
        lat = cell["latency"]
        lines.append(
            f"| {cell['system']} | {cell['env']} | {cell['max_slots']} "
            f"| {lat['p50_ms']:.2f} | {lat['p99_ms']:.2f} "
            f"| {cell['decisions_per_sec']:,.0f} "
            f"| {cell['episodes']} | {cell['episode_return_mean']:.3f} |"
        )
    return "\n".join(lines) + "\n"


def main():
    run(parse_args())


if __name__ == "__main__":
    main()
