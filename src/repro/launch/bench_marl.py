"""Training-throughput launcher — the speed half of the measurement backbone.

Times every runner rung (python loop / fused Anakin / shard_map) and the
serial-vs-vmapped-seed speedup for a systems x envs slice, and writes the
``BENCH_speed.json`` + ``BENCH_speed.md`` perf-trajectory artifact (schema
in docs/BENCH.md, validated by ``scripts/check_bench_schema.py``).

  # the default slice (vdn + ippo + rec_ippo on matrix_game + spread + lbf)
  PYTHONPATH=src python -m repro.launch.bench_marl

  # CI smoke scale
  PYTHONPATH=src python -m repro.launch.bench_marl --systems vdn ippo \
      --envs matrix_game --iterations 64 --num-envs 4 --num-seeds 4
"""
from __future__ import annotations

import argparse
import contextlib

from repro.bench.throughput import run_bench
from repro.envs import REGISTRY as ENVS
from repro.obs import ConsoleSink, profile_trace
from repro.systems.registry import REGISTRY as SYSTEMS


def main():
    p = argparse.ArgumentParser()
    p.add_argument(
        "--systems", nargs="+", choices=sorted(SYSTEMS) + ["all"],
        default=["vdn", "ippo", "rec_ippo"],
        help="systems to bench (default: one replay, one on-policy and "
        "one recurrent family)",
    )
    p.add_argument(
        "--envs", nargs="+", choices=sorted(ENVS) + ["all"],
        default=["matrix_game", "spread", "lbf"],
        help="envs to bench (default: the cheapest classic pair plus one "
        "gridworld, covering the fused-recurrent rung's pinned envs)",
    )
    p.add_argument("--iterations", type=int, default=256,
                   help="fused-runner training iterations per timed call")
    p.add_argument("--num-envs", type=int, default=4,
                   help="vmapped envs per run (and per device for shard_map)")
    p.add_argument("--num-seeds", type=int, default=8,
                   help="seeds for the serial-vs-vmapped comparison")
    p.add_argument("--loop-episodes", type=int, default=3,
                   help="episodes for the python-loop baseline timing")
    p.add_argument("--out", default="BENCH_speed.json")
    p.add_argument(
        "--profile", default=None, metavar="DIR",
        help="capture a jax.profiler trace of the whole bench into DIR "
        "(see docs/OBSERVABILITY.md on reading traces)",
    )
    args = p.parse_args()

    system_names = sorted(SYSTEMS) if "all" in args.systems else args.systems
    env_names = sorted(ENVS) if "all" in args.envs else args.envs
    trace_ctx = (
        profile_trace(args.profile) if args.profile
        else contextlib.nullcontext({})
    )
    with trace_ctx as trace_info:
        run_bench(
            system_names=system_names,
            env_names=env_names,
            iterations=args.iterations,
            num_envs=args.num_envs,
            num_seeds=args.num_seeds,
            loop_episodes=args.loop_episodes,
            out_path=args.out,
        )
    if args.profile:
        ConsoleSink().write(trace_info)


if __name__ == "__main__":
    main()
