"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim; 0 -> d_ff
    capacity_factor: float = 1.25
    moe_group_size: int = 512  # tokens per dispatch group
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # mamba2 head size
    ssm_chunk: int = 128    # chunk length for scans
    mamba_version: int = 1

    # --- hybrid (zamba2-style shared attention) ---
    attn_every: int = 0  # apply the shared attention block after every N core layers
    shared_attn: bool = False

    # --- attention variants ---
    attn_window: int = 0  # 0 = full causal; >0 = sliding window size
    # window used when constructing the long_500k variant of an attention
    # arch (dense/vlm/audio/hybrid); see launch.dryrun.shape_config
    long_context_window: int = 8192
    rope_theta: float = 10000.0
    attn_chunk: int = 512  # query-block size for the chunked jnp attention path

    # --- multimodal ---
    num_codebooks: int = 0   # audio: EnCodec codebooks
    vision_tokens: int = 0   # vlm: number of patch-embedding tokens prepended

    # --- distribution ---
    sharding: str = "tp"  # "tp" | "fsdp_tp" | "fsdp_tp_sp" (distributed.sharding)
    grad_accum: int = 1   # microbatches per train_step (activation memory / k)
    # save post-collective layer outputs under remat so backward does not
    # re-run forward all-reduces (communication-avoiding remat policy)
    save_layer_outputs: bool = False
    # compute only the causally-live key blocks per query block (unrolled
    # static slices instead of the scanned full-row sweep): ~2x attention
    # FLOP reduction at larger HLO size
    attn_causal_skip: bool = False
    # flash-decoding-style KV cache sharding: shard the cache's sequence dim
    # over the model axis (softmax combines via two small all-reduces) —
    # the lever for GQA archs whose n_kv < model-axis size, where head
    # sharding can't apply and replicated 32k caches blow past HBM
    shard_kv_seq: bool = False

    # --- numerics / training ---
    dtype: str = "bfloat16"
    remat: bool = True
    tie_embeddings: bool = False
    xent_chunk: int = 512  # sequence-chunk for large-vocab softmax xent
    use_pallas: bool = False  # TPU path; CPU dry-run/tests use jnp reference

    # citation for the assigned config
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.arch_type == "moe" and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return max(1, self.d_inner // self.ssm_head_dim)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def num_attn_invocations(self) -> int:
        """Shared-attention invocations in a hybrid stack."""
        if not self.attn_every:
            return 0
        return self.num_layers // self.attn_every

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-flops)."""
        d, L, v = self.d_model, self.num_layers, self.vocab
        hd = self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.num_codebooks:
            emb = self.num_codebooks * v * d * 2
        per_layer = 0
        if self.arch_type in ("dense", "vlm", "audio"):
            attn = d * hd * (nq + 2 * nkv) + nq * hd * d
            mlp = 3 * d * self.d_ff
            per_layer = attn + mlp + 2 * d
        elif self.arch_type == "moe":
            attn = d * hd * (nq + 2 * nkv) + nq * hd * d
            moe = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
            per_layer = attn + moe + 2 * d
        elif self.arch_type in ("ssm", "hybrid"):
            di, n = self.d_inner, self.ssm_state
            if self.mamba_version == 1:
                dt_rank = max(1, d // 16)
                per_layer = (
                    d * 2 * di          # in_proj
                    + di * self.ssm_conv
                    + di * (dt_rank + 2 * n)  # x_proj
                    + dt_rank * di      # dt_proj
                    + di * n + di       # A_log, D
                    + di * d            # out_proj
                    + d
                )
            else:
                h = self.ssm_heads
                per_layer = (
                    d * (2 * di + 2 * n + h)  # in_proj (z,x,B,C,dt)
                    + (di + 2 * n) * self.ssm_conv
                    + h + h                   # A_log, D
                    + di * d
                    + d
                )
        total = emb + L * per_layer
        if self.arch_type == "hybrid" and self.shared_attn:
            attn = d * hd * (nq + 2 * nkv) + nq * hd * d
            total += attn + 3 * d * self.d_ff + 2 * d
        return int(total)

    def flops_param_count(self) -> int:
        """Params as-if-unshared: weight-shared blocks (zamba2's shared
        attention) are counted once per *invocation*, so 6*N*D reflects the
        compute actually performed rather than unique parameters."""
        n = self.active_param_count()
        if self.arch_type == "hybrid" and self.shared_attn and self.attn_every:
            d, hd = self.d_model, self.head_dim
            nq, nkv = self.num_heads, self.num_kv_heads
            attn = d * hd * (nq + 2 * nkv) + nq * hd * d
            shared = attn + 3 * d * self.d_ff + 2 * d
            n += shared * (self.num_attn_invocations - 1)
        return int(n)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE activates top_k of num_experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd, nq, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (nq + 2 * nkv) + nq * hd * d
        moe_active = self.top_k * 3 * d * self.moe_d_ff + d * self.num_experts
        return int(emb + L * (attn + moe_active + 2 * d))


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def get_input_shape(name: str) -> InputShape:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
