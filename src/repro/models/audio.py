"""MusicGen codebook-interleaving utilities (delay pattern).

MusicGen decodes K EnCodec codebooks with a *delay* interleave: codebook k is
shifted right by k steps so that at generation step t the model predicts
codebook k's token for frame t-k. apply/revert are exact inverses over the
valid region; shifted-in slots hold `pad_id`.
"""
from __future__ import annotations

import jax.numpy as jnp


def apply_delay_pattern(tokens, pad_id: int):
    """tokens: (B, S, K) -> delayed (B, S, K)."""
    B, S, K = tokens.shape
    cols = []
    for k in range(K):
        col = tokens[:, : S - k, k]
        col = jnp.pad(col, ((0, 0), (k, 0)), constant_values=pad_id)
        cols.append(col)
    return jnp.stack(cols, axis=-1)


def revert_delay_pattern(tokens, pad_id: int):
    """Inverse of apply_delay_pattern; trailing slots become pad_id."""
    B, S, K = tokens.shape
    cols = []
    for k in range(K):
        col = tokens[:, k:, k]
        col = jnp.pad(col, ((0, 0), (0, k)), constant_values=pad_id)
        cols.append(col)
    return jnp.stack(cols, axis=-1)
