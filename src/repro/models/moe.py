"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

GShard/Switch-style einsum dispatch: tokens are processed in groups of
`cfg.moe_group_size`; each group builds a (g, E, C) dispatch tensor where
C = ceil(g * top_k / E * capacity_factor). Experts are sharded over the
"model" mesh axis (expert parallelism); groups are sharded over the data
axes, so the dispatch einsums induce all-to-all-like resharding between the
token-sharded and expert-sharded layouts — exactly the communication pattern
the roofline's collective term tracks.

Aux losses: load-balance (Switch) + router z-loss, returned per call and
averaged by the caller.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint
from repro.models.layers import _trunc_normal


def init_moe(key, cfg):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    dtype = cfg.activation_dtype
    p = {
        "router": _trunc_normal(k1, (d, E), s_in, jnp.float32),
        "w_gate": _trunc_normal(k2, (E, d, ff), s_in, dtype),
        "w_up": _trunc_normal(k3, (E, d, ff), s_in, dtype),
        "w_down": _trunc_normal(k4, (E, ff, d), s_out, dtype),
    }
    a = {
        "router": ("embed", None),
        "w_gate": ("expert", "embed", "expert_ffn"),
        "w_up": ("expert", "embed", "expert_ffn"),
        "w_down": ("expert", "expert_ffn", "embed"),
    }
    return p, a


def expert_capacity(group_size: int, num_experts: int, top_k: int, factor: float) -> int:
    return max(1, int(math.ceil(group_size * top_k / num_experts * factor)))


def top_k_routing(router_logits, top_k: int, capacity: int):
    """Build dispatch/combine tensors.

    router_logits: (G, g, E) fp32.
    Returns:
      dispatch: (G, g, E, C) bool — token->slot assignment
      combine:  (G, g, E, C) f32  — gate-weighted dispatch
      aux_loss, z_loss: scalars
    """
    G, g, E = router_logits.shape
    probs = jax.nn.softmax(router_logits, axis=-1)  # (G,g,E)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (G,g,k)
    # renormalise selected gates (standard for top-k>1 routing)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Position of each (token, choice) in its expert's buffer. Priority:
    # choice rank first (all 1st choices beat 2nd choices), then token order.
    dispatch = jnp.zeros((G, g, E, capacity), jnp.bool_)
    combine = jnp.zeros((G, g, E, capacity), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for k in range(top_k):
        mask_k = jax.nn.one_hot(gate_idx[..., k], E, dtype=jnp.int32)  # (G,g,E)
        pos_in_expert = jnp.cumsum(mask_k, axis=1) - 1 + counts[:, None, :]
        counts = counts + jnp.sum(mask_k, axis=1)
        keep = (pos_in_expert < capacity) & (mask_k > 0)
        slot_oh = jax.nn.one_hot(
            jnp.clip(pos_in_expert, 0, capacity - 1), capacity, dtype=jnp.float32
        )  # (G,g,E,C)
        sel = keep[..., None] * slot_oh
        dispatch = dispatch | (sel > 0)
        combine = combine + sel * gate_vals[..., k][..., None, None]

    # Switch load-balance loss: E * sum_e (frac_tokens_e * mean_prob_e)
    frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(frac * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.scipy.special.logsumexp(router_logits, axis=-1)))
    return dispatch, combine, aux_loss, z_loss


def moe_ffn(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, aux_loss, z_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    tokens = B * S
    g = min(cfg.moe_group_size, tokens)
    pad = (-tokens) % g  # pad ragged tails; padded rows' outputs are dropped
    G = (tokens + pad) // g
    C = expert_capacity(g, E, k, cfg.capacity_factor)

    xflat = x.reshape(tokens, d)
    if pad:
        xflat = jnp.pad(xflat, ((0, pad), (0, 0)))
    xg = xflat.reshape(G, g, d)
    logits = (xg.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    dispatch, combine, aux, z = top_k_routing(logits, k, C)

    dtype = x.dtype
    # dispatch tokens to expert buffers: (G,E,C,d)
    expert_in = jnp.einsum("gtd,gtec->gecd", xg, dispatch.astype(dtype))
    expert_in = with_logical_constraint(expert_in, ("batch", "expert", None, "embed"))
    # expert FFN, batched over E
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    h = with_logical_constraint(h, ("batch", "expert", None, "expert_ffn"))
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    expert_out = with_logical_constraint(expert_out, ("batch", "expert", None, "embed"))
    # combine back to token order
    y = jnp.einsum("gecd,gtec->gtd", expert_out, combine.astype(dtype))
    y = y.reshape(G * g, d)[:tokens]
    return y.reshape(B, S, d), aux, z
