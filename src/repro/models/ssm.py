"""Mamba1 selective scan and Mamba2 (SSD) blocks.

TPU adaptation notes (see DESIGN.md §3):
- The CUDA selective-scan kernel keeps state in registers while streaming the
  sequence. The jnp training path here uses an *outer scan over chunks* whose
  carried state (B, d_inner, N) is the only tensor saved for backward; each
  chunk's inner per-step scan is wrapped in jax.checkpoint and recomputed.
  The Pallas kernel (repro/kernels/selective_scan) is the TPU-native version:
  grid over (batch, d_inner blocks), state resident in VMEM.
- Mamba2 uses the SSD block-decomposition: intra-chunk attention-like matmuls
  (MXU-friendly) + inter-chunk state recurrence, scanned chunk-by-chunk.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint
from repro.models.layers import _trunc_normal, causal_depthwise_conv1d


# ================================================================= Mamba 1


def dt_rank(cfg) -> int:
    return max(1, cfg.d_model // 16)


def init_mamba1(key, cfg):
    d, di, n, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    r = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    dtype = cfg.activation_dtype
    s = 1.0 / math.sqrt(d)
    # S4D-real initialisation for A
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init_std = r**-0.5
    p = {
        "in_proj": _trunc_normal(ks[0], (d, 2 * di), s, dtype),
        "conv_w": _trunc_normal(ks[1], (di, K), 1.0 / math.sqrt(K), jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _trunc_normal(ks[2], (di, r + 2 * n), 1.0 / math.sqrt(di), dtype),
        "dt_proj_w": _trunc_normal(ks[3], (r, di), dt_init_std, jnp.float32),
        "dt_proj_b": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(ks[4], (di,))
                    * (math.log(0.1) - math.log(0.001))
                    + math.log(0.001)
                )
            )
            - 1.0
        ),  # inverse-softplus of dt in [1e-3, 1e-1]
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _trunc_normal(ks[5], (di, d), 1.0 / math.sqrt(di), dtype),
    }
    a = {
        "in_proj": ("embed", "dinner"),
        "conv_w": ("dinner", None),
        "conv_b": ("dinner",),
        "x_proj": ("dinner", None),
        "dt_proj_w": (None, "dinner"),
        "dt_proj_b": ("dinner",),
        "A_log": ("dinner", "state"),
        "D": ("dinner",),
        "out_proj": ("dinner", "embed"),
    }
    return p, a


def selective_scan_chunked(x, delta, A, B, C, D, chunk: int):
    """Mamba1 recurrence, jnp reference with chunked remat.

    x, delta: (b, S, di); A: (di, N); B, C: (b, S, N); D: (di,)
    h_t = exp(delta_t A) * h_{t-1} + (delta_t * x_t) outer B_t
    y_t = (h_t . C_t) + D * x_t
    Returns (y: (b,S,di), h_final: (b,di,N)).
    """
    b, S, di = x.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # padded steps have delta=0 -> exp(0)=1, zero input: state unchanged
        zpad2 = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        x, delta, B, C = zpad2(x), zpad2(delta), zpad2(B), zpad2(C)
    Sp = S + pad
    nc = Sp // chunk

    def step(h, inp):
        x_t, d_t, B_t, C_t = inp  # (b,di),(b,di),(b,N),(b,N)
        dA = jnp.exp(d_t[..., None] * A)  # (b,di,N)
        dBx = (d_t * x_t)[..., None] * B_t[:, None, :]  # (b,di,N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    @jax.checkpoint
    def chunk_fn(h, inp_chunk):
        # cast to fp32 chunk-locally: the full-sequence streams stay in the
        # model dtype (halves the scan's HBM traffic vs wholesale pre-cast)
        xs = jax.tree_util.tree_map(
            lambda t: t.astype(jnp.float32).swapaxes(0, 1), inp_chunk
        )
        h, ys = jax.lax.scan(step, h, xs)
        return h, ys.swapaxes(0, 1)  # (b,chunk,di)

    def outer(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=1)
        h, y = chunk_fn(h, (sl(x), sl(delta), sl(B), sl(C)))
        return h, y

    h0 = jnp.zeros((b, di, N), jnp.float32)
    h_final, ys = jax.lax.scan(outer, h0, jnp.arange(nc))
    y = ys.swapaxes(0, 1).reshape(b, Sp, di)[:, :S]
    y = y + x[:, :S].astype(jnp.float32) * D
    return y.astype(x.dtype), h_final


def mamba1_forward(params, x, cfg, conv_state=None, ssm_state=None):
    """Full-sequence (train/prefill) mamba1 block. x: (B,S,d).

    Returns (y, (conv_state, ssm_state)) — states are the final ones, used
    as the decode cache after prefill.
    """
    B_, S, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    r = dt_rank(cfg)

    xz = x @ params["in_proj"]  # (B,S,2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = with_logical_constraint(xs, ("batch", None, "dinner"))

    # conv in the model dtype (bf16): halves the conv's HBM traffic; the
    # bias add upcasts to fp32 before the activation
    conv_out = causal_depthwise_conv1d(
        xs, params["conv_w"].astype(xs.dtype)
    ).astype(jnp.float32) + params["conv_b"]
    new_conv_state = xs[:, S - (cfg.ssm_conv - 1) :].astype(jnp.float32)
    xs = jax.nn.silu(conv_out).astype(x.dtype)

    proj = xs @ params["x_proj"]  # (B,S,r+2n)
    dt_r, Bm, Cm = jnp.split(proj, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ params["dt_proj_w"] + params["dt_proj_b"]
    )
    A = -jnp.exp(params["A_log"])

    if cfg.use_pallas:
        # TPU path: VMEM-resident-state Pallas kernel (returns final state)
        from repro.kernels.selective_scan.ops import selective_scan

        y, h_final = selective_scan(
            xs, delta, A, Bm, Cm, params["D"], chunk=cfg.ssm_chunk
        )
    else:
        y, h_final = selective_scan_chunked(
            xs, delta, A, Bm, Cm, params["D"], cfg.ssm_chunk
        )
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return with_logical_constraint(out, ("batch", None, "embed")), (
        new_conv_state,
        h_final,
    )


def mamba1_decode(params, x, conv_state, ssm_state, cfg):
    """Single-token decode. x: (B,1,d); conv_state: (B,K-1,di) fp32;
    ssm_state: (B,di,N) fp32. Returns (y, (conv_state, ssm_state))."""
    n = cfg.ssm_state
    r = dt_rank(cfg)

    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    conv_out, new_conv_state = causal_depthwise_conv1d(
        xs.astype(jnp.float32), params["conv_w"], state=conv_state
    )
    xs = jax.nn.silu(conv_out + params["conv_b"]).astype(x.dtype)  # (B,1,di)

    proj = xs @ params["x_proj"]
    dt_r, Bm, Cm = jnp.split(proj, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ params["dt_proj_w"] + params["dt_proj_b"]
    )  # (B,1,di)
    A = -jnp.exp(params["A_log"])

    x_t = xs[:, 0].astype(jnp.float32)
    d_t = delta[:, 0]
    B_t = Bm[:, 0].astype(jnp.float32)
    C_t = Cm[:, 0].astype(jnp.float32)
    dA = jnp.exp(d_t[..., None] * A)
    h = dA * ssm_state + (d_t * x_t)[..., None] * B_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_t) + params["D"] * x_t
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, (new_conv_state, h)


# ================================================================= Mamba 2


def init_mamba2(key, cfg):
    d, di, n, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    h = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    dtype = cfg.activation_dtype
    s = 1.0 / math.sqrt(d)
    conv_dim = di + 2 * n
    p = {
        "in_proj": _trunc_normal(ks[0], (d, 2 * di + 2 * n + h), s, dtype),
        "conv_w": _trunc_normal(ks[1], (conv_dim, K), 1.0 / math.sqrt(K), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(ks[2], (h,))
                    * (math.log(0.1) - math.log(0.001))
                    + math.log(0.001)
                )
            )
            - 1.0
        ),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (h,), minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": _trunc_normal(
            jax.random.fold_in(key, 7), (di, d), 1.0 / math.sqrt(di), dtype
        ),
    }
    a = {
        "in_proj": ("embed", "dinner"),
        "conv_w": ("dinner", None),
        "conv_b": ("dinner",),
        "dt_bias": (None,),
        "A_log": (None,),
        "D": (None,),
        "norm_scale": ("dinner",),
        "out_proj": ("dinner", "embed"),
    }
    return p, a


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """Mamba2 SSD, scanning chunk-by-chunk.

    x: (b,S,h,p); dt: (b,S,h) (post-softplus); A: (h,) negative;
    B, C: (b,S,n); D: (h,). Returns (y: (b,S,h,p), state: (b,h,n,p)).
    """
    b, S, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    Sp = S + pad
    nc = Sp // chunk

    if pad:  # dt=0 padding: decay exp(0)=1, zero input — state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    @jax.checkpoint
    def chunk_fn(state, args):
        # chunk-local fp32 casting (see selective_scan_chunked)
        xc, dtc, Bc, Cc = (t.astype(jnp.float32) for t in args)
        a = dtc * A  # (b,l,h)  negative
        cum = jnp.cumsum(a, axis=1)  # (b,l,h)
        # intra-chunk: M[i,j] = C_i.B_j * exp(cum_i - cum_j) for j<=i
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc)  # (b,l,l)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (b,i,j,h)
        li = jnp.arange(xc.shape[1])
        causal = (li[:, None] >= li[None, :]).astype(jnp.float32)
        M = scores[..., None] * decay * causal[None, :, :, None]  # (b,i,j,h)
        xdt = xc * dtc[..., None]  # (b,l,h,p)
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xdt)
        # inter-chunk: contribution of carried state
        decay_from_start = jnp.exp(cum)  # (b,l,h)
        y_inter = jnp.einsum(
            "bin,bhnp,bih->bihp", Cc, state, decay_from_start
        )
        # new state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (b,l,h)
        state_contrib = jnp.einsum(
            "bjn,bjhp,bjh->bhnp", Bc, xdt, decay_to_end
        )
        new_state = jnp.exp(cum[:, -1])[:, :, None, None] * state + state_contrib
        y = y_intra + y_inter + D[None, None, :, None] * xc
        return new_state, y

    def outer(state, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=1)
        return chunk_fn(state, (sl(x), sl(dt), sl(B), sl(C)))

    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    state, ys = jax.lax.scan(outer, state0, jnp.arange(nc))
    y = ys.swapaxes(0, 1).reshape(b, Sp, h, p)[:, :S]
    return y.astype(x.dtype), state


def _rmsnorm_gated(x, z, scale, eps=1e-6):
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _split_mamba2_proj(proj, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di : di + di + 2 * n]
    dt = proj[..., di + di + 2 * n :]
    return z, xBC, dt


def mamba2_forward(params, x, cfg):
    """Full-sequence mamba2 block. x: (B,S,d) -> (y, (conv_state, ssm_state))."""
    B_, S, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p_dim = cfg.ssm_head_dim

    proj = x @ params["in_proj"]
    z, xBC, dt = _split_mamba2_proj(proj, cfg)
    xBC = with_logical_constraint(xBC, ("batch", None, "dinner"))

    conv_out = causal_depthwise_conv1d(
        xBC, params["conv_w"].astype(xBC.dtype)
    ).astype(jnp.float32) + params["conv_b"]
    new_conv_state = xBC[:, S - (cfg.ssm_conv - 1) :].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(x.dtype)

    xs = xBC[..., :di].reshape(B_, S, h, p_dim)
    Bm = xBC[..., di : di + n]
    Cm = xBC[..., di + n :]
    delta = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, state = ssd_chunked(xs, delta, A, Bm, Cm, params["D"], cfg.ssm_chunk)
    y = y.reshape(B_, S, di)
    y = _rmsnorm_gated(y, z, params["norm_scale"])
    out = y @ params["out_proj"]
    return with_logical_constraint(out, ("batch", None, "embed")), (
        new_conv_state,
        state,
    )


def mamba2_decode(params, x, conv_state, ssm_state, cfg):
    """Single-token mamba2 decode. ssm_state: (B,h,n,p) fp32."""
    B_ = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p_dim = cfg.ssm_head_dim

    proj = x @ params["in_proj"]
    z, xBC, dt = _split_mamba2_proj(proj, cfg)
    conv_out, new_conv_state = causal_depthwise_conv1d(
        xBC.astype(jnp.float32), params["conv_w"], state=conv_state
    )
    xBC = jax.nn.silu(conv_out + params["conv_b"]).astype(x.dtype)  # (B,1,·)

    xs = xBC[..., :di].reshape(B_, h, p_dim)
    Bm = xBC[:, 0, di : di + n].astype(jnp.float32)
    Cm = xBC[:, 0, di + n :].astype(jnp.float32)
    delta = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,h)
    A = -jnp.exp(params["A_log"])

    dA = jnp.exp(delta * A)  # (B,h)
    xdt = xs.astype(jnp.float32) * delta[..., None]  # (B,h,p)
    new_ssm = dA[..., None, None] * ssm_state + jnp.einsum("bn,bhp->bhnp", Bm, xdt)
    y = jnp.einsum("bn,bhnp->bhp", Cm, new_ssm) + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, 1, di).astype(x.dtype)
    y = _rmsnorm_gated(y, z, params["norm_scale"])
    out = y @ params["out_proj"]
    return out, (new_conv_state, new_ssm)
