from repro.models.config import ModelConfig
from repro.models.model import (
    init_model,
    forward_train,
    prefill,
    decode_step,
    init_cache,
    model_flops_per_token,
)

__all__ = [
    "ModelConfig",
    "init_model",
    "forward_train",
    "prefill",
    "decode_step",
    "init_cache",
    "model_flops_per_token",
]
