"""LM backbone assembly for all assigned architecture families.

Public API (all pure functions over param pytrees):

  init_model(key, cfg)            -> params
  model_axes(cfg)                 -> pytree of logical-axis tuples (matches params)
  forward_train(params, batch, cfg) -> (loss, metrics)
  init_cache(cfg, batch, max_len) -> cache pytree
  prefill(params, batch, cfg)     -> (last_hidden_logits, cache)
  decode_step(params, cache, tokens, cfg) -> (logits, new_cache)

Layers are stacked along a leading L dim and driven by lax.scan (compact HLO
even for 126-layer configs); each scan body is jax.checkpoint-ed when
cfg.remat. Hybrid (zamba2-style) stacks mamba2 layers and applies a *shared*
attention+MLP block (single param set) after every cfg.attn_every layers,
each invocation with its own KV-cache slice.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    chunked_softmax_xent,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    init_unembed,
    mlp,
    rmsnorm,
)

# ----------------------------------------------------------------- blocks


def core_kind(cfg: ModelConfig) -> str:
    if cfg.arch_type in ("dense", "vlm", "audio"):
        return "dense"
    if cfg.arch_type == "moe":
        return "moe"
    if cfg.arch_type == "ssm":
        return f"mamba{cfg.mamba_version}"
    if cfg.arch_type == "hybrid":
        return "mamba2"
    raise ValueError(cfg.arch_type)


def init_block(key, cfg: ModelConfig):
    """One core layer. Returns (params, axes)."""
    kind = core_kind(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "dense":
        pa, aa = attn_lib.init_attention(k1, cfg)
        pm, am = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation_dtype)
        pn1, an1 = init_rmsnorm(cfg.d_model)
        pn2, an2 = init_rmsnorm(cfg.d_model)
        return (
            {"norm1": pn1, "attn": pa, "norm2": pn2, "mlp": pm},
            {"norm1": an1, "attn": aa, "norm2": an2, "mlp": am},
        )
    if kind == "moe":
        pa, aa = attn_lib.init_attention(k1, cfg)
        pm, am = moe_lib.init_moe(k2, cfg)
        pn1, an1 = init_rmsnorm(cfg.d_model)
        pn2, an2 = init_rmsnorm(cfg.d_model)
        return (
            {"norm1": pn1, "attn": pa, "norm2": pn2, "moe": pm},
            {"norm1": an1, "attn": aa, "norm2": an2, "moe": am},
        )
    if kind == "mamba1":
        pm, am = ssm_lib.init_mamba1(k1, cfg)
        pn, an = init_rmsnorm(cfg.d_model)
        return {"norm": pn, "mamba": pm}, {"norm": an, "mamba": am}
    if kind == "mamba2":
        pm, am = ssm_lib.init_mamba2(k1, cfg)
        pn, an = init_rmsnorm(cfg.d_model)
        return {"norm": pn, "mamba": pm}, {"norm": an, "mamba": am}
    raise ValueError(kind)


def init_shared_attn(key, cfg: ModelConfig):
    """Zamba2-style shared transformer block (attention + MLP, one param set)."""
    k1, k2 = jax.random.split(key)
    pa, aa = attn_lib.init_attention(k1, cfg)
    pm, am = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation_dtype)
    pn1, an1 = init_rmsnorm(cfg.d_model)
    pn2, an2 = init_rmsnorm(cfg.d_model)
    return (
        {"norm1": pn1, "attn": pa, "norm2": pn2, "mlp": pm},
        {"norm1": an1, "attn": aa, "norm2": an2, "mlp": am},
    )


def _stack_axes(axes):
    """Prepend the stacked-layer dim (unsharded) to every leaf."""
    return jax.tree_util.tree_map(
        lambda a: (None, *a), axes, is_leaf=lambda x: isinstance(x, tuple)
    )


# ------------------------------------------------------------------ model


def init_model(key, cfg: ModelConfig):
    params, _ = _init_model_with_axes(key, cfg)
    return params


def model_axes(cfg: ModelConfig):
    """Logical-axis tree matching init_model's output (no arrays created)."""
    _, block_axes = _eval_axes(lambda k: init_block(k, cfg))
    out = {"layers": _stack_axes(block_axes)}
    _, emb_axes = _eval_axes(
        lambda k: _init_embed_group(k, cfg)
    )
    out.update(emb_axes)
    if cfg.arch_type == "hybrid" and cfg.shared_attn:
        _, sa = _eval_axes(lambda k: init_shared_attn(k, cfg))
        out["shared_attn"] = sa
    return out


def _eval_axes(fn):
    """Run an init fn abstractly, returning (param_shapes, axes)."""
    axes_box = {}

    def wrapped(k):
        p, a = fn(k)
        axes_box["axes"] = a
        return p

    shapes = jax.eval_shape(wrapped, jax.random.key(0))
    return shapes, axes_box["axes"]


def _init_embed_group(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    params, axes = {}, {}
    if cfg.num_codebooks:
        # audio: per-codebook embeddings and heads
        def emb_init(k):
            p, _ = init_embedding(k, cfg.vocab, cfg.d_model, cfg.activation_dtype)
            return p

        def head_init(k):
            p, _ = init_unembed(k, cfg.d_model, cfg.vocab, cfg.activation_dtype)
            return p

        params["embed"] = jax.vmap(emb_init)(
            jax.random.split(k1, cfg.num_codebooks)
        )
        axes["embed"] = {"embedding": ("codebooks", "vocab", "embed")}
        params["unembed"] = jax.vmap(head_init)(
            jax.random.split(k2, cfg.num_codebooks)
        )
        axes["unembed"] = {"w": ("codebooks", "embed", "vocab")}
    else:
        pe, ae = init_embedding(k1, cfg.vocab, cfg.d_model, cfg.activation_dtype)
        params["embed"] = pe
        axes["embed"] = ae
        if not cfg.tie_embeddings:
            pu, au = init_unembed(k2, cfg.d_model, cfg.vocab, cfg.activation_dtype)
            params["unembed"] = pu
            axes["unembed"] = au
    pn, an = init_rmsnorm(cfg.d_model)
    params["final_norm"] = pn
    axes["final_norm"] = an
    return params, axes


def _init_model_with_axes(key, cfg: ModelConfig):
    k_layers, k_emb, k_shared = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)

    _, block_axes = _eval_axes(lambda k: init_block(k, cfg))

    def block_params_only(k):
        p, _ = init_block(k, cfg)
        return p

    stacked = jax.vmap(block_params_only)(layer_keys)
    params = {"layers": stacked}
    axes = {"layers": _stack_axes(block_axes)}

    emb_p, emb_a = _init_embed_group(k_emb, cfg)
    params.update(emb_p)
    axes.update(emb_a)

    if cfg.arch_type == "hybrid" and cfg.shared_attn:
        sp, sa = init_shared_attn(k_shared, cfg)
        params["shared_attn"] = sp
        axes["shared_attn"] = sa
    return params, axes


# --------------------------------------------------------------- embedding


def _embed_tokens(params, batch, cfg: ModelConfig):
    """Returns (h, text_offset) — text_offset is #prefix tokens (vlm)."""
    if cfg.arch_type == "audio":
        # tokens: (B,S,K) — sum codebook embeddings
        embs = params["embed"]["embedding"]  # (K, vocab, d)
        return _audio_embed(embs, batch["tokens"]), 0
    if cfg.arch_type == "vlm":
        text = embed(params["embed"], batch["tokens"])  # (B,T,d)
        vision = batch["vision_embeds"].astype(text.dtype)  # (B,V,d)
        return jnp.concatenate([vision, text], axis=1), vision.shape[1]
    return embed(params["embed"], batch["tokens"]), 0


def _audio_embed(embs, toks):
    """embs: (K,V,d); toks: (B,S,K) -> (B,S,d) summed over codebooks."""
    K = embs.shape[0]
    h = 0.0
    for k in range(K):
        h = h + jnp.take(embs[k], toks[..., k], axis=0)
    return h


def _unembed_weight(params, cfg: ModelConfig):
    if cfg.num_codebooks:
        return params["unembed"]["w"]  # (K,d,V)
    if cfg.tie_embeddings:
        return params["embed"]["embedding"].T
    return params["unembed"]["w"]


# ------------------------------------------------------------- layer scan


def _ckpt_name(x, cfg: ModelConfig):
    """Tag post-collective sublayer outputs so the remat policy can save
    them — backward then never re-runs the forward all-reduces."""
    if cfg.save_layer_outputs:
        from jax.ad_checkpoint import checkpoint_name

        return checkpoint_name(x, "layer_out")
    return x


def _remat(body, cfg: ModelConfig):
    if not cfg.remat:
        return body
    if cfg.save_layer_outputs:
        policy = jax.checkpoint_policies.save_only_these_names("layer_out")
        return jax.checkpoint(body, policy=policy)
    return jax.checkpoint(body)


def _run_layers_train(params, h, cfg: ModelConfig):
    """Scan all layers (training/prefill, no cache). Returns (h, aux_losses)."""
    L = cfg.num_layers
    positions = jnp.arange(h.shape[1])
    shared = params.get("shared_attn")

    def body(carry, xs):
        h, aux, zl = carry
        layer_params, idx = xs
        kind = core_kind(cfg)
        if kind == "dense":
            h = h + _ckpt_name(
                attn_lib.attention_full(
                    layer_params["attn"], rmsnorm(layer_params["norm1"], h), positions, cfg
                ),
                cfg,
            )
            h = h + _ckpt_name(
                mlp(layer_params["mlp"], rmsnorm(layer_params["norm2"], h)), cfg
            )
        elif kind == "moe":
            h = h + _ckpt_name(
                attn_lib.attention_full(
                    layer_params["attn"], rmsnorm(layer_params["norm1"], h), positions, cfg
                ),
                cfg,
            )
            y, a, z = moe_lib.moe_ffn(
                layer_params["moe"], rmsnorm(layer_params["norm2"], h), cfg
            )
            h = h + _ckpt_name(y, cfg)
            aux, zl = aux + a, zl + z
        else:  # mamba1 / mamba2
            fwd = ssm_lib.mamba1_forward if kind == "mamba1" else ssm_lib.mamba2_forward
            y, _ = fwd(layer_params["mamba"], rmsnorm(layer_params["norm"], h), cfg)
            h = h + _ckpt_name(y, cfg)
            if shared is not None and cfg.attn_every:
                def run_shared(h):
                    hh = h + _ckpt_name(
                        attn_lib.attention_full(
                            shared["attn"], rmsnorm(shared["norm1"], h), positions, cfg
                        ),
                        cfg,
                    )
                    return hh + _ckpt_name(
                        mlp(shared["mlp"], rmsnorm(shared["norm2"], hh)), cfg
                    )

                h = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0, run_shared, lambda h: h, h
                )
        h = with_logical_constraint(h, ("batch", "seq", "embed"))
        return (h, aux, zl), None

    body_fn = _remat(body, cfg)
    (h, aux, zl), _ = jax.lax.scan(
        body_fn,
        (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (params["layers"], jnp.arange(L)),
    )
    return h, (aux / L, zl / L)


# ---------------------------------------------------------------- training


def forward_train(params, batch, cfg: ModelConfig):
    """Next-token loss. batch keys: tokens, labels (+ vision_embeds for vlm).

    Returns (loss, metrics dict).
    """
    h, text_offset = _embed_tokens(params, batch, cfg)
    h = with_logical_constraint(h, ("batch", "seq", "embed"))
    h, (aux, zl) = _run_layers_train(params, h, cfg)
    h = rmsnorm(params["final_norm"], h)

    w = _unembed_weight(params, cfg)
    labels = batch["labels"]
    if cfg.arch_type == "vlm":
        # predictions for text token i come from position V-1+i
        T = labels.shape[1]
        h = jax.lax.dynamic_slice_in_dim(h, text_offset - 1, T, axis=1)
    if cfg.num_codebooks:
        # (B,S,K) labels; per-codebook heads
        losses = []
        for k in range(cfg.num_codebooks):
            losses.append(
                chunked_softmax_xent(h, w[k], labels[..., k], cfg.xent_chunk)
            )
        lm_loss = jnp.mean(jnp.stack(losses))
    else:
        lm_loss = chunked_softmax_xent(h, w, labels, cfg.xent_chunk)

    loss = lm_loss
    metrics = {"lm_loss": lm_loss}
    if cfg.arch_type == "moe":
        loss = loss + cfg.router_aux_weight * aux + cfg.router_z_weight * zl
        metrics.update({"router_aux": aux, "router_z": zl})
    metrics["loss"] = loss
    return loss, metrics


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS/token = 6 * N (the roofline's useful-compute term).

    N counts MoE active params only and weight-shared blocks once per
    invocation (flops_param_count) — 6ND should reflect useful compute,
    not unique-parameter storage.
    """
    return 6.0 * cfg.flops_param_count()


# ------------------------------------------------------------------ cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache for one generation stream set."""
    kind = core_kind(cfg)
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}  # per-stream positions
    if kind in ("dense", "moe"):
        cache["kv"] = attn_lib.init_kv_cache(cfg, batch, max_len)
    elif kind == "mamba1":
        cache["conv"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32
        )
        cache["ssm"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.d_inner, cfg.ssm_state), jnp.float32
        )
    elif kind == "mamba2":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        cache["conv"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv - 1, conv_dim), jnp.float32
        )
        cache["ssm"] = jnp.zeros(
            (
                cfg.num_layers,
                batch,
                cfg.ssm_heads,
                cfg.ssm_state,
                cfg.ssm_head_dim,
            ),
            jnp.float32,
        )
        if cfg.shared_attn and cfg.attn_every:
            cache["kv"] = attn_lib.init_kv_cache(
                cfg, batch, max_len, n_layers=cfg.num_attn_invocations
            )
    return cache


def cache_axes(cfg: ModelConfig):
    kind = core_kind(cfg)
    axes = {"pos": ("batch",)}
    if kind in ("dense", "moe"):
        axes["kv"] = attn_lib.kv_cache_axes(cfg)
    elif kind == "mamba1":
        axes["conv"] = (None, "batch", None, "dinner")
        axes["ssm"] = (None, "batch", "dinner", None)
    elif kind == "mamba2":
        axes["conv"] = (None, "batch", None, "dinner")
        axes["ssm"] = (None, "batch", None, None, None)
        if cfg.shared_attn and cfg.attn_every:
            axes["kv"] = attn_lib.kv_cache_axes(cfg)
    return axes


# ------------------------------------------------------------------ decode


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One-token decode. tokens: (B,1) int (audio: (B,1,K)).

    Returns (logits, new_cache). logits: (B,1,V) (audio: (B,1,K,V)).
    """
    kind = core_kind(cfg)
    pos = cache["pos"]
    if cfg.arch_type == "audio":
        embs = params["embed"]["embedding"]
        h = _audio_embed(embs, tokens)
    else:
        h = embed(params["embed"], tokens)
    h = with_logical_constraint(h, ("batch", None, "embed"))
    shared = params.get("shared_attn")
    new_cache = dict(cache)

    if kind in ("dense", "moe"):
        def body(h, xs):
            layer_params, kc, vc = xs
            y, upd = attn_lib.attention_decode(
                layer_params["attn"],
                rmsnorm(layer_params["norm1"], h),
                {"k": kc, "v": vc},
                pos,
                cfg,
            )
            h = h + y
            if kind == "moe":
                y2, _, _ = moe_lib.moe_ffn(
                    layer_params["moe"], rmsnorm(layer_params["norm2"], h), cfg
                )
            else:
                y2 = mlp(layer_params["mlp"], rmsnorm(layer_params["norm2"], h))
            h = h + y2
            return h, (upd["k"], upd["v"])

        h, (k_new, v_new) = jax.lax.scan(
            body, h, (params["layers"], cache["kv"]["k"], cache["kv"]["v"])
        )
        new_cache["kv"] = {"k": k_new, "v": v_new}
    else:
        dec = ssm_lib.mamba1_decode if kind == "mamba1" else ssm_lib.mamba2_decode
        n_inv = cfg.num_attn_invocations if (shared is not None and cfg.attn_every) else 0

        def body(carry, xs):
            h, kv = carry
            layer_params, conv, ssm_state, idx = xs
            y, (conv_new, ssm_new) = dec(
                layer_params["mamba"], rmsnorm(layer_params["norm"], h), conv, ssm_state, cfg
            )
            h = h + y
            if n_inv:
                def run_shared(args):
                    h, kv = args
                    inv = jnp.minimum((idx + 1) // cfg.attn_every - 1, n_inv - 1)
                    layer_kv = {
                        "k": kv["k"][inv],
                        "v": kv["v"][inv],
                    }
                    y, upd = attn_lib.attention_decode(
                        shared["attn"], rmsnorm(shared["norm1"], h), layer_kv, pos, cfg
                    )
                    hh = h + y
                    hh = hh + mlp(shared["mlp"], rmsnorm(shared["norm2"], hh))
                    kv = {
                        "k": kv["k"].at[inv].set(upd["k"]),
                        "v": kv["v"].at[inv].set(upd["v"]),
                    }
                    return hh, kv

                h, kv = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0,
                    run_shared,
                    lambda args: args,
                    (h, kv),
                )
            return (h, kv), (conv_new, ssm_new)

        kv0 = cache.get("kv", {"k": jnp.zeros((1,)), "v": jnp.zeros((1,))})
        (h, kv), (conv_new, ssm_new) = jax.lax.scan(
            body,
            (h, kv0),
            (params["layers"], cache["conv"], cache["ssm"], jnp.arange(cfg.num_layers)),
        )
        new_cache["conv"] = conv_new
        new_cache["ssm"] = ssm_new
        if "kv" in cache:
            new_cache["kv"] = kv

    h = rmsnorm(params["final_norm"], h)
    w = _unembed_weight(params, cfg)
    if cfg.num_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", h, w)
    else:
        logits = h @ w
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ----------------------------------------------------------------- prefill


def prefill(params, batch, cfg: ModelConfig, max_len: Optional[int] = None):
    """Process a full prompt, returning (last-position logits, decode cache).

    For attention archs this runs the training-style forward but additionally
    materialises per-layer K/V into a fresh cache; for SSM archs it returns
    the final recurrent states. `max_len` sizes the cache for subsequent
    decode_steps (defaults to the prompt length).
    """
    kind = core_kind(cfg)
    h, _ = _embed_tokens(params, batch, cfg)
    h = with_logical_constraint(h, ("batch", None, "embed"))
    B, S = h.shape[0], h.shape[1]
    positions = jnp.arange(S)
    shared = params.get("shared_attn")

    cache = init_cache(cfg, B, max_len or S)

    if kind in ("dense", "moe"):
        C = cache["kv"]["k"].shape[2]

        def body(h, xs):
            layer_params, idx = xs
            xn = rmsnorm(layer_params["norm1"], h)
            q, k, v = attn_lib._qkv(layer_params["attn"], xn, positions, cfg)
            n_rep = cfg.num_heads // cfg.num_kv_heads
            out = attn_lib.chunked_causal_attention(
                q,
                attn_lib._expand_kv(k, n_rep),
                attn_lib._expand_kv(v, n_rep),
                cfg.attn_window,
                cfg.attn_chunk,
                causal_skip=cfg.attn_causal_skip,
            )
            y = jnp.einsum("bshk,hkd->bsd", out, layer_params["attn"]["wo"])
            h = h + y
            if kind == "moe":
                y2, _, _ = moe_lib.moe_ffn(
                    layer_params["moe"], rmsnorm(layer_params["norm2"], h), cfg
                )
            else:
                y2 = mlp(layer_params["mlp"], rmsnorm(layer_params["norm2"], h))
            h = h + y2
            k_keep = attn_lib.place_kv_in_cache(k, C).astype(cache["kv"]["k"].dtype)
            v_keep = attn_lib.place_kv_in_cache(v, C).astype(cache["kv"]["v"].dtype)
            return h, (k_keep, v_keep)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, (k_all, v_all) = jax.lax.scan(
            body_fn, h, (params["layers"], jnp.arange(cfg.num_layers))
        )
        cache["kv"] = {"k": k_all, "v": v_all}
    else:
        n_inv = cfg.num_attn_invocations if (shared is not None and cfg.attn_every) else 0
        if n_inv:
            C = cache["kv"]["k"].shape[2]

        def body(carry, xs):
            h, kv = carry
            layer_params, idx = xs
            fwd = ssm_lib.mamba1_forward if kind == "mamba1" else ssm_lib.mamba2_forward
            y, (conv_s, ssm_s) = fwd(
                layer_params["mamba"], rmsnorm(layer_params["norm"], h), cfg
            )
            h = h + y
            if n_inv:
                def run_shared(args):
                    h, kv = args
                    inv = jnp.minimum((idx + 1) // cfg.attn_every - 1, n_inv - 1)
                    xn = rmsnorm(shared["norm1"], h)
                    q, k, v = attn_lib._qkv(shared["attn"], xn, positions, cfg)
                    n_rep = cfg.num_heads // cfg.num_kv_heads
                    out = attn_lib.chunked_causal_attention(
                        q,
                        attn_lib._expand_kv(k, n_rep),
                        attn_lib._expand_kv(v, n_rep),
                        cfg.attn_window,
                        cfg.attn_chunk,
                        causal_skip=cfg.attn_causal_skip,
                    )
                    y = jnp.einsum("bshk,hkd->bsd", out, shared["attn"]["wo"])
                    hh = h + y
                    hh = hh + mlp(shared["mlp"], rmsnorm(shared["norm2"], hh))
                    kv = {
                        "k": kv["k"].at[inv].set(
                            attn_lib.place_kv_in_cache(k, C).astype(kv["k"].dtype)
                        ),
                        "v": kv["v"].at[inv].set(
                            attn_lib.place_kv_in_cache(v, C).astype(kv["v"].dtype)
                        ),
                    }
                    return hh, kv

                h, kv = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0,
                    run_shared,
                    lambda args: args,
                    (h, kv),
                )
            return (h, kv), (conv_s, ssm_s)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        kv0 = cache.get("kv", {"k": jnp.zeros((1,)), "v": jnp.zeros((1,))})
        (h, kv), (conv_all, ssm_all) = jax.lax.scan(
            body_fn, (h, kv0), (params["layers"], jnp.arange(cfg.num_layers))
        )
        cache["conv"] = conv_all
        cache["ssm"] = ssm_all
        if "kv" in cache:
            cache["kv"] = kv

    h = rmsnorm(params["final_norm"], h)
    last = h[:, -1:]
    w = _unembed_weight(params, cfg)
    if cfg.num_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", last, w)
    else:
        logits = last @ w
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits, cache
