"""GQA attention: training/prefill (chunked online-softmax) + cached decode.

The full-sequence path processes query blocks of `cfg.attn_chunk` with an
online-softmax accumulator (a jnp re-statement of flash attention — the Pallas
kernel in repro/kernels/flash_attention is the TPU version). This keeps peak
activation memory at O(B * H * chunk * S) instead of O(B * H * S^2), which is
what makes the 32k prefill shapes lower with sane memory analysis.

Sliding-window attention (cfg.attn_window > 0) masks keys older than the
window during training/prefill and uses a ring-buffer KV cache for decode —
that is what makes `long_500k` sub-quadratic (O(S * W)) for dense archs.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint
from repro.models.layers import apply_rope, _trunc_normal

NEG_INF = -1e30


def init_attention(key, cfg):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(nq * hd)
    dtype = cfg.activation_dtype
    p = {
        "wq": _trunc_normal(k1, (d, nq, hd), s, dtype),
        "wk": _trunc_normal(k2, (d, nkv, hd), s, dtype),
        "wv": _trunc_normal(k3, (d, nkv, hd), s, dtype),
        "wo": _trunc_normal(k4, (nq, hd, d), so, dtype),
    }
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return p, a


def _qkv(params, x, positions, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = with_logical_constraint(q, ("batch", None, "heads", None))
    k = with_logical_constraint(k, ("batch", None, "kv_heads", None))
    v = with_logical_constraint(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _expand_kv(k, n_rep):
    """(B,S,nkv,hd) -> (B,S,nq,hd) by repeating each kv head n_rep times."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def chunked_causal_attention(q, k, v, window: int, chunk: int, causal_skip: bool = False):
    """Online-softmax attention over query blocks.

    q,k,v: (B,S,H,hd) with H already expanded to query heads.
    window: 0 for full causal, else sliding window length.
    causal_skip: compute only the causally-live key prefix per query block
      (static slices, unrolled over blocks) — halves attention FLOPs/bytes
      versus the scanned full-row sweep at the price of a larger HLO.
    Returns (B,S,H,hd).
    """
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # pad queries to a chunk multiple; extra rows trimmed at the end
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sq = S + pad
    n_blocks = Sq // chunk

    kT = k.swapaxes(1, 2)  # (B,H,S,hd)
    vT = v.swapaxes(1, 2)
    qT = q.swapaxes(1, 2).reshape(B, H, n_blocks, chunk, hd)
    del q

    key_pos = jnp.arange(S)

    if causal_skip and not window:
        # Unrolled block-triangular sweep: block i attends keys [0,(i+1)*chunk)
        def make_tri_block(i: int):
            q_pos = i * chunk + jnp.arange(chunk)
            kv_len = min((i + 1) * chunk, S)

            @jax.checkpoint
            def tri_block(qb, kT_i, vT_i):
                scores = jnp.einsum(
                    "bhqk,bhsk->bhqs", qb.astype(jnp.float32), kT_i.astype(jnp.float32)
                ) * scale
                mask = key_pos[:kv_len][None, :] <= q_pos[:, None]
                scores = jnp.where(mask[None, None], scores, NEG_INF)
                w = jax.nn.softmax(scores, axis=-1)
                return jnp.einsum("bhqs,bhsk->bhqk", w, vT_i.astype(jnp.float32))

            return tri_block, kv_len

        outs = []
        for i in range(n_blocks):
            tri_block, kv_len = make_tri_block(i)
            outs.append(tri_block(qT[:, :, i], kT[:, :, :kv_len], vT[:, :, :kv_len]))
        out = jnp.stack(outs, axis=2).reshape(B, H, Sq, hd)[:, :, :S]
        return out.swapaxes(1, 2).astype(k.dtype)

    @jax.checkpoint
    def block(qb, block_idx):
        # qb: (B,H,chunk,hd)
        q_pos = block_idx * chunk + jnp.arange(chunk)
        scores = jnp.einsum("bhqk,bhsk->bhqs", qb.astype(jnp.float32), kT.astype(jnp.float32))
        scores = scores * scale
        mask = key_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= key_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqs,bhsk->bhqk", w, vT.astype(jnp.float32))

    def body(_, args):
        qb, idx = args
        return None, block(qb, idx)

    _, out = jax.lax.scan(body, None, (qT.swapaxes(0, 2).swapaxes(1, 2), jnp.arange(n_blocks)))
    # out: (n_blocks, B, H, chunk, hd)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, hd)[:, :, :S]
    return out.swapaxes(1, 2).astype(k.dtype)


def attention_full(params, x, positions, cfg):
    """Training / prefill attention. x: (B,S,d) -> (B,S,d)."""
    q, k, v = _qkv(params, x, positions, cfg)
    if cfg.use_pallas:
        # TPU path: the Pallas flash kernel (GQA-aware — no head expansion)
        from repro.kernels.flash_attention.ops import flash_attention

        out = flash_attention(
            q.swapaxes(1, 2),
            k.swapaxes(1, 2),
            v.swapaxes(1, 2),
            causal=True,
            window=cfg.attn_window,
        ).swapaxes(1, 2)
    else:
        n_rep = cfg.num_heads // cfg.num_kv_heads
        k = _expand_kv(k, n_rep)
        v = _expand_kv(v, n_rep)
        out = chunked_causal_attention(
            q, k, v, cfg.attn_window, cfg.attn_chunk, causal_skip=cfg.attn_causal_skip
        )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return with_logical_constraint(y, ("batch", None, "embed"))


# ------------------------------------------------------------------ decode


def init_kv_cache(cfg, batch, max_len, n_layers=None, dtype=None):
    """Ring-buffer (windowed) or linear KV cache.

    Layout: (L, B, C, n_kv, hd) where C = min(max_len, window or max_len).
    """
    L = cfg.num_layers if n_layers is None else n_layers
    C = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    dtype = dtype or cfg.activation_dtype
    shape = (L, batch, C, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def place_kv_in_cache(k, C):
    """Lay out prompt K/V (B,S,nkv,hd) into a capacity-C cache.

    Position p lives at slot p % C (ring layout used by attention_decode).
    If C >= S the prompt occupies slots 0..S-1 (rest zero/unwritten); else
    the last C positions are kept, rolled so slot p % C holds position p.
    """
    S = k.shape[1]
    if C >= S:
        return jnp.pad(k, ((0, 0), (0, C - S), (0, 0), (0, 0)))
    return jnp.roll(k[:, S - C :], shift=S % C, axis=1)


def kv_cache_axes(cfg=None):
    seq = "kv_seq" if (cfg is not None and cfg.shard_kv_seq) else None
    return {
        "k": (None, "batch", seq, "kv_heads", None),
        "v": (None, "batch", seq, "kv_heads", None),
    }


def attention_decode(params, x, layer_cache, pos, cfg):
    """Single-token decode. x: (B,1,d); layer_cache: {k,v}: (B,C,n_kv,hd);
    pos: (B,) int32 — per-stream number of tokens already in context
    (a scalar is broadcast), enabling continuous batching where streams are
    at different depths.

    Returns (y, new_layer_cache).
    """
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]
    q, k_new, v_new = _qkv(params, x, positions, cfg)

    C = layer_cache["k"].shape[1]
    write_idx = (pos % C) if cfg.attn_window else jnp.minimum(pos, C - 1)
    bidx = jnp.arange(B)
    k_cache = layer_cache["k"].at[bidx, write_idx].set(
        k_new[:, 0].astype(layer_cache["k"].dtype)
    )
    v_cache = layer_cache["v"].at[bidx, write_idx].set(
        v_new[:, 0].astype(layer_cache["v"].dtype)
    )

    n_rep = cfg.num_heads // cfg.num_kv_heads
    k = _expand_kv(k_cache, n_rep)  # (B,C,H,hd)
    v = _expand_kv(v_cache, n_rep)

    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.einsum(
        "bqhk,bshk->bhqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # (B,H,1,C)

    slot = jnp.arange(C)
    if cfg.attn_window:
        # valid slots: written (slot <= pos when pos < C) and within window
        age = (write_idx[:, None] - slot[None, :]) % C  # 0 = current token
        valid = age <= jnp.minimum(pos, C - 1)[:, None]  # (B,C)
    else:
        valid = slot[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", w, v.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    y = with_logical_constraint(y, ("batch", None, "embed"))
    return y, {"k": k_cache, "v": v_cache}
