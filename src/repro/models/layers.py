"""Function-style layers shared by all LM backbones.

Each init_* returns (params, axes) where axes mirrors params with tuples of
logical axis names for repro.distributed.sharding.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import with_logical_constraint


def _trunc_normal(key, shape, stddev, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(dtype)


# ---------------------------------------------------------------- RMSNorm


def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(params, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# ---------------------------------------------------------------- Embedding


def init_embedding(key, vocab, d, dtype):
    p = {"embedding": _trunc_normal(key, (vocab, d), 1.0, dtype)}
    a = {"embedding": ("vocab", "embed")}
    return p, a


def embed(params, ids):
    return jnp.take(params["embedding"], ids, axis=0)


def init_unembed(key, d, vocab, dtype):
    p = {"w": _trunc_normal(key, (d, vocab), 1.0 / math.sqrt(d), dtype)}
    a = {"w": ("embed", "vocab")}
    return p, a


# ---------------------------------------------------------------- RoPE


def rope_frequencies(head_dim, theta):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- SwiGLU MLP


def init_mlp(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_gate": _trunc_normal(k1, (d, d_ff), s_in, dtype),
        "w_up": _trunc_normal(k2, (d, d_ff), s_in, dtype),
        "w_down": _trunc_normal(k3, (d_ff, d), s_out, dtype),
    }
    a = {
        "w_gate": ("embed", "ffn"),
        "w_up": ("embed", "ffn"),
        "w_down": ("ffn", "embed"),
    }
    return p, a


def mlp(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = with_logical_constraint(h, ("batch", None, "ffn"))
    return h @ params["w_down"]


# ------------------------------------------------- chunked softmax x-entropy


def softmax_xent_logits(logits, labels):
    """Per-token cross entropy from logits; fp32 reductions."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def chunked_softmax_xent(x, w_unembed, labels, chunk, mask=None):
    """Mean next-token loss without materialising (B,S,V) logits.

    Scans over sequence chunks; each chunk's logits are rematerialised on the
    backward pass (jax.checkpoint), so peak memory is O(B*chunk*V). The Pallas
    `fused_xent` kernel is the TPU version of the same blocking.

    x: (B,S,d), labels: (B,S) int, mask: optional (B,S) weighting.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    @jax.checkpoint
    def chunk_loss(xc, lc, mc):
        logits = xc @ w_unembed  # (B,c,V)
        losses = softmax_xent_logits(logits, lc)
        return jnp.sum(losses * mc), jnp.sum(mc)

    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    def body(carry, args):
        tot, cnt = carry
        xc, lc, mc = args
        s, c = chunk_loss(xc, lc, mc)
        return (tot + s, cnt + c), None

    xs = (
        x[:, : n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1),
        labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1),
        mask[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1),
    )
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), xs)
    if rem:
        s, c = chunk_loss(x[:, n * chunk :], labels[:, n * chunk :], mask[:, n * chunk :])
        tot, cnt = tot + s, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------- conv1d


def causal_depthwise_conv1d(x, weight, state=None):
    """Depthwise causal conv over time. x: (B,S,C), weight: (C,K).

    If `state` is given it is the last K-1 inputs (B,K-1,C) and x is a single
    step (B,1,C); returns (y, new_state).
    """
    K = weight.shape[-1]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)  # (B,K,C)
        y = jnp.einsum("bkc,ck->bc", window, weight)[:, None]
        return y, window[:, 1:]
    # Sum of K shifted copies — avoids materialising (B,S,K,C) windows.
    S = x.shape[1]
    y = x * weight[:, K - 1]
    for k in range(K - 1):
        shift = K - 1 - k
        shifted = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        y = y + shifted * weight[:, k]
    return y
