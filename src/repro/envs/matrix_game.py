"""Iterated cooperative matrix games (climbing / penalty).

Classic 2-agent coordination testbeds: both agents pick one of K actions;
the shared reward is payoff[a0, a1]. Observations are the one-hot of the
previous joint action (zeros on the first step), so recurrent or
feed-forward policies can both be probed. An episode is `horizon` steps.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import (
    ArraySpec,
    DiscreteSpec,
    EnvSpec,
    agent_ids,
    restart,
    transition,
)

CLIMBING = jnp.array(
    [[11.0, -30.0, 0.0], [-30.0, 7.0, 6.0], [0.0, 0.0, 5.0]]
)
PENALTY = jnp.array(
    [[10.0, 0.0, -10.0], [0.0, 2.0, 0.0], [-10.0, 0.0, 10.0]]
)


class MatrixGameState(NamedTuple):
    """Matrix-game state: step count + previous joint action."""
    t: jnp.ndarray
    last_joint: jnp.ndarray  # (2,) int32


@dataclasses.dataclass(frozen=True)
class MatrixGame:
    """Iterated cooperative matrix game (climbing payoff by default)."""
    payoff: jnp.ndarray = None  # (K,K)
    horizon: int = 10

    def __post_init__(self):
        if self.payoff is None:
            object.__setattr__(self, "payoff", CLIMBING)

    @property
    def num_agents(self):
        """Number of agents."""
        return 2

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return agent_ids(2)

    @property
    def num_actions(self):
        """Number of discrete actions per agent."""
        return self.payoff.shape[0]

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        K = self.num_actions
        obs = ArraySpec((2 * K,))
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={a: obs for a in self.agent_ids},
            actions={a: DiscreteSpec(K) for a in self.agent_ids},
            state=ArraySpec((2 * K,)),
        )

    def _obs(self, state: MatrixGameState):
        K = self.num_actions
        valid = state.t > 0
        oh = jax.nn.one_hot(state.last_joint, K).reshape(-1) * valid
        return {a: oh for a in self.agent_ids}

    def global_state(self, state: MatrixGameState):
        """The global state vector (centralised training input)."""
        K = self.num_actions
        valid = state.t > 0
        return jax.nn.one_hot(state.last_joint, K).reshape(-1) * valid

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        del key
        state = MatrixGameState(
            t=jnp.zeros((), jnp.int32), last_joint=jnp.zeros((2,), jnp.int32)
        )
        return state, restart(self.agent_ids, self._obs(state))

    def step(self, state: MatrixGameState, actions):
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        a0 = actions["agent_0"]
        a1 = actions["agent_1"]
        r = self.payoff[a0, a1]
        t = state.t + 1
        new_state = MatrixGameState(t=t, last_joint=jnp.stack([a0, a1]))
        done = t >= self.horizon
        return new_state, transition(self.agent_ids, r, self._obs(new_state), done)
