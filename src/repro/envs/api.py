"""Multi-agent environment API (dm_env-style, pure functional for JAX).

Mirrors the paper's multi-agent TimeStep/specs: observations and rewards are
dicts keyed by agent id; discount and step_type are shared. Environments are
dataclasses of pure functions:

    state, ts = env.reset(key)
    state, ts = env.step(state, actions)     # actions: dict agent -> int

so a whole env is vmap-able across parallel copies and scannable across time
— the property that lets Mava-JAX fuse env stepping into the training jit
(the Anakin architecture) instead of paying a python/gRPC round trip per
step as in the Acme/Reverb original.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax.numpy as jnp


class StepType:
    FIRST = 0
    MID = 1
    LAST = 2


class TimeStep(NamedTuple):
    step_type: jnp.ndarray            # () int32
    reward: Dict[str, jnp.ndarray]    # per-agent scalar
    discount: jnp.ndarray             # () shared
    observation: Dict[str, jnp.ndarray]

    def first(self):
        return self.step_type == StepType.FIRST

    def last(self):
        return self.step_type == StepType.LAST


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class DiscreteSpec:
    num_values: int
    dtype: Any = jnp.int32

    @property
    def shape(self):
        return ()


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Multi-agent spec: per-agent observation/action specs + global state."""

    agent_ids: Tuple[str, ...]
    observations: Dict[str, ArraySpec]
    actions: Dict[str, Any]  # DiscreteSpec or ArraySpec (continuous)
    state: ArraySpec  # global state (for centralised critics / QMIX)

    @property
    def num_agents(self) -> int:
        return len(self.agent_ids)


def agent_ids(n: int) -> Tuple[str, ...]:
    return tuple(f"agent_{i}" for i in range(n))


def shared_reward(ids, value) -> Dict[str, jnp.ndarray]:
    return {a: value for a in ids}
