"""Multi-agent environment API (dm_env-style, pure functional for JAX).

Mirrors the paper's multi-agent TimeStep/specs: observations and rewards are
dicts keyed by agent id; discount and step_type are shared. Environments are
dataclasses of pure functions:

    state, ts = env.reset(key)
    state, ts = env.step(state, actions)     # actions: dict agent -> int

so a whole env is vmap-able across parallel copies and scannable across time
— the property that lets Mava-JAX fuse env stepping into the training jit
(the Anakin architecture) instead of paying a python/gRPC round trip per
step as in the Acme/Reverb original.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax.numpy as jnp


class StepType:
    """dm_env-style step-type codes (FIRST/MID/LAST)."""
    FIRST = 0
    MID = 1
    LAST = 2


class TimeStep(NamedTuple):
    """One multi-agent env emission (step type, rewards, discount, obs)."""
    step_type: jnp.ndarray            # () int32
    reward: Dict[str, jnp.ndarray]    # per-agent scalar
    discount: jnp.ndarray             # () shared
    observation: Dict[str, jnp.ndarray]

    def first(self):
        """True when this is the FIRST step of an episode."""
        return self.step_type == StepType.FIRST

    def last(self):
        """True when this is the LAST step of an episode."""
        return self.step_type == StepType.LAST


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Shape/dtype contract for one array-valued stream."""
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class DiscreteSpec:
    """Spec for a discrete action with ``num_values`` choices."""
    num_values: int
    dtype: Any = jnp.int32

    @property
    def shape(self):
        """Scalar: discrete actions are rank-0."""
        return ()


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Multi-agent spec: per-agent observation/action specs + global state."""

    agent_ids: Tuple[str, ...]
    observations: Dict[str, ArraySpec]
    actions: Dict[str, Any]  # DiscreteSpec or ArraySpec (continuous)
    state: ArraySpec  # global state (for centralised critics / QMIX)

    @property
    def num_agents(self) -> int:
        """Number of agents."""
        return len(self.agent_ids)


def agent_ids(n: int) -> Tuple[str, ...]:
    """The canonical ``agent_0..agent_{n-1}`` id tuple."""
    return tuple(f"agent_{i}" for i in range(n))


def shared_reward(ids, value) -> Dict[str, jnp.ndarray]:
    """Broadcast one shared reward value to every agent id."""
    return {a: value for a in ids}


# ------------------------------------------------------- TimeStep factories
# The shared reset/step plumbing every env used to hand-roll: `reset`
# returns ``restart(...)``, `step` returns ``transition(...)``, and the
# step-type/discount bookkeeping lives in exactly one place.


def restart(ids, observation) -> TimeStep:
    """The FIRST TimeStep of an episode: zero rewards, discount one."""
    return TimeStep(
        step_type=jnp.asarray(StepType.FIRST, jnp.int32),
        reward=shared_reward(ids, jnp.zeros(())),
        discount=jnp.ones(()),
        observation=observation,
    )


def transition(ids, reward, observation, done) -> TimeStep:
    """A MID/LAST TimeStep from one env step.

    ``reward`` is either a shared scalar (broadcast to every agent — the
    cooperative convention) or a per-agent dict (general-sum / per-agent
    reward regimes). ``done`` selects LAST + zero discount.
    """
    if not isinstance(reward, dict):
        reward = shared_reward(ids, reward)
    return TimeStep(
        step_type=jnp.where(done, StepType.LAST, StepType.MID).astype(jnp.int32),
        reward=reward,
        discount=jnp.where(done, 0.0, 1.0),
        observation=observation,
    )
