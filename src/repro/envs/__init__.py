"""Pure-functional multi-agent envs: `REGISTRY`, `make_env`, wrapper stack."""
from repro.envs.api import TimeStep, EnvSpec, ArraySpec, DiscreteSpec, StepType
from repro.envs.matrix_game import MatrixGame
from repro.envs.switch_game import SwitchGame
from repro.envs.spread import Spread
from repro.envs.speaker_listener import SpeakerListener
from repro.envs.smax_lite import SmaxLite
from repro.envs.robot_warehouse import RobotWarehouse
from repro.envs.lbf import LevelBasedForaging
from repro.envs.wrappers import (
    AgentIdObs,
    AutoReset,
    ConcatObsState,
    EpisodeStats,
    Wrapper,
)


def _gridworld(cls):
    """Registry factory for the gridworld family: raw dynamics + the
    standard observation stack (one-hot agent ids for shared-weight
    policies, concat-of-observations global state for centralised
    critics) built from wrappers instead of per-env code."""

    def factory(**kwargs):
        """Build the wrapped gridworld env with the registered stack."""
        return ConcatObsState(AgentIdObs(cls(**kwargs)))

    factory.__name__ = f"make_{cls.__name__}"
    factory.__doc__ = f"Wrapped {cls.__name__} (AgentIdObs + ConcatObsState)."
    return factory


REGISTRY = {
    "matrix_game": MatrixGame,
    "switch_game": SwitchGame,
    "spread": Spread,
    "speaker_listener": SpeakerListener,
    "smax_lite": SmaxLite,
    "robot_warehouse": _gridworld(RobotWarehouse),
    "lbf": _gridworld(LevelBasedForaging),
}


def make_env(name: str, **kwargs):
    """Build a registered environment by name (the sweep/launcher entry)."""
    if name not in REGISTRY:
        raise KeyError(f"unknown env {name!r}; registered: {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)


__all__ = [
    "TimeStep",
    "EnvSpec",
    "ArraySpec",
    "DiscreteSpec",
    "StepType",
    "MatrixGame",
    "SwitchGame",
    "Spread",
    "SpeakerListener",
    "SmaxLite",
    "RobotWarehouse",
    "LevelBasedForaging",
    "Wrapper",
    "AgentIdObs",
    "AutoReset",
    "ConcatObsState",
    "EpisodeStats",
    "REGISTRY",
    "make_env",
]
