from repro.envs.api import TimeStep, EnvSpec, ArraySpec, DiscreteSpec, StepType
from repro.envs.matrix_game import MatrixGame
from repro.envs.switch_game import SwitchGame
from repro.envs.spread import Spread
from repro.envs.speaker_listener import SpeakerListener
from repro.envs.smax_lite import SmaxLite

REGISTRY = {
    "matrix_game": MatrixGame,
    "switch_game": SwitchGame,
    "spread": Spread,
    "speaker_listener": SpeakerListener,
    "smax_lite": SmaxLite,
}


def make_env(name: str, **kwargs):
    """Build a registered environment by name (the sweep/launcher entry)."""
    if name not in REGISTRY:
        raise KeyError(f"unknown env {name!r}; registered: {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)


__all__ = [
    "TimeStep",
    "EnvSpec",
    "ArraySpec",
    "DiscreteSpec",
    "StepType",
    "MatrixGame",
    "SwitchGame",
    "Spread",
    "SpeakerListener",
    "SmaxLite",
    "REGISTRY",
    "make_env",
]
