"""MPE simple-speaker-listener (Lowe et al. 2017) in pure JAX.

Speaker (static) observes the target landmark colour and utters one of C
discrete symbols; listener observes the utterance + relative landmark
positions and must move to the target. Shared reward = -dist(listener,
target). The classic asymmetric-information cooperative task from the
paper's Fig. 6 experiments.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import (
    ArraySpec,
    DiscreteSpec,
    EnvSpec,
    restart,
    transition,
)

_DIRS = jnp.array([[0.0, 0.0], [1.0, 0.0], [-1.0, 0.0], [0.0, 1.0], [0.0, -1.0]])


class SLState(NamedTuple):
    """Speaker-listener env state (target, listener pose, message)."""
    t: jnp.ndarray
    listener_pos: jnp.ndarray  # (2,)
    listener_vel: jnp.ndarray  # (2,)
    landmarks: jnp.ndarray     # (C,2)
    target: jnp.ndarray        # () int
    last_msg: jnp.ndarray      # () int


@dataclasses.dataclass(frozen=True)
class SpeakerListener:
    """Cooperative speaker-listener: speaker signals the goal landmark."""
    num_landmarks: int = 3
    horizon: int = 25
    dt: float = 0.1
    damping: float = 0.25
    accel: float = 5.0

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return ("speaker", "listener")

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        C = self.num_landmarks
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={
                "speaker": ArraySpec((C,)),  # one-hot target colour
                # vel(2) + rel landmarks (2C) + msg one-hot (C)
                "listener": ArraySpec((2 + 2 * C + C,)),
            },
            actions={
                "speaker": DiscreteSpec(C),
                "listener": DiscreteSpec(5),
            },
            state=ArraySpec((2 + 2 + 2 * C + C + C,)),
        )

    def _obs(self, state: SLState):
        C = self.num_landmarks
        rel = (state.landmarks - state.listener_pos).reshape(-1)
        msg = jax.nn.one_hot(state.last_msg, C)
        return {
            "speaker": jax.nn.one_hot(state.target, C),
            "listener": jnp.concatenate([state.listener_vel, rel, msg]),
        }

    def global_state(self, state: SLState):
        """The global state vector (centralised training input)."""
        C = self.num_landmarks
        return jnp.concatenate(
            [
                state.listener_pos,
                state.listener_vel,
                (state.landmarks - state.listener_pos).reshape(-1),
                jax.nn.one_hot(state.target, C),
                jax.nn.one_hot(state.last_msg, C),
            ]
        )

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        k1, k2, k3 = jax.random.split(key, 3)
        lm = jax.random.uniform(k1, (self.num_landmarks, 2), minval=-1.0, maxval=1.0)
        pos = jax.random.uniform(k2, (2,), minval=-1.0, maxval=1.0)
        target = jax.random.randint(k3, (), 0, self.num_landmarks)
        state = SLState(
            t=jnp.zeros((), jnp.int32),
            listener_pos=pos,
            listener_vel=jnp.zeros((2,)),
            landmarks=lm,
            target=target,
            last_msg=jnp.zeros((), jnp.int32),
        )
        return state, restart(self.agent_ids, self._obs(state))

    def step(self, state: SLState, actions):
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        msg = actions["speaker"]
        f = _DIRS[actions["listener"]] * self.accel
        vel = state.listener_vel * (1.0 - self.damping) + f * self.dt
        pos = jnp.clip(state.listener_pos + vel * self.dt, -1.5, 1.5)
        t = state.t + 1
        r = -jnp.linalg.norm(pos - state.landmarks[state.target])
        new_state = SLState(
            t=t,
            listener_pos=pos,
            listener_vel=vel,
            landmarks=state.landmarks,
            target=state.target,
            last_msg=msg,
        )
        done = t >= self.horizon
        return new_state, transition(self.agent_ids, r, self._obs(new_state), done)
