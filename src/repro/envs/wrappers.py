"""Composable environment wrappers (the plumbing layer of `repro.envs`).

Every wrapper is a frozen dataclass around an inner env and preserves the
pure-functional env contract — ``reset(key)``, ``step(state, actions)``,
``global_state(state)``, ``spec()`` — so wrapped envs stay vmap-able across
copies and scannable across time (the Anakin fusion property).  Wrappers
compose freely; attributes they don't override (``horizon``, ``agent_ids``,
...) delegate to the inner env.

Two families:

* **observation wrappers** (state passes through unchanged):
    - `AgentIdObs` — append a one-hot agent id to every observation, so
      shared-weight policies on homogeneous envs stay agent-aware;
    - `ConcatObsState` — synthesize the global state (centralised critics,
      QMIX mixers) as the concatenation of all agents' observations, for
      envs whose observations jointly carry the full state.
* **stream wrappers** (wrap the state in their own NamedTuple):
    - `AutoReset` — fused auto-reset: when the inner env terminates, the
      state is reset *in the same step* and the returned timestep is the
      FIRST of the new episode carrying the terminal reward/discount
      (Brax/Jumanji-style merged boundary; see the class docstring);
    - `EpisodeStats` — accumulate per-agent episode returns and lengths
      inside the state, publishing them at every episode boundary.

The three runners in `repro.core.system` build their reset/global-state
plumbing from this stack instead of per-runner ad-hoc code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import ArraySpec, TimeStep


@dataclasses.dataclass(frozen=True)
class Wrapper:
    """Base wrapper: delegate the env protocol (and any attribute) inward."""

    env: Any

    def __getattr__(self, name):
        # only reached for attributes not defined on the wrapper itself
        return getattr(self.env, name)

    def spec(self):
        """Delegate to the inner env."""
        return self.env.spec()

    def reset(self, key):
        """Delegate to the inner env."""
        return self.env.reset(key)

    def step(self, state, actions):
        """Delegate to the inner env."""
        return self.env.step(state, actions)

    def global_state(self, state):
        """Delegate to the inner env."""
        return self.env.global_state(state)


# ------------------------------------------------------ observation wrappers


@dataclasses.dataclass(frozen=True)
class AgentIdObs(Wrapper):
    """Append a one-hot agent id to every agent's observation.

    The standard trick for shared-weight policies on homogeneous envs
    (Mava/JaxMARL's ``add_agent_id``): identical network weights can still
    condition on *which* agent they are acting for.
    """

    def spec(self):
        """The inner spec with the one-hot id appended to each obs spec."""
        spec = self.env.spec()
        n = spec.num_agents
        obs = {
            a: ArraySpec((spec.observations[a].shape[0] + n,), spec.observations[a].dtype)
            for a in spec.agent_ids
        }
        return dataclasses.replace(spec, observations=obs)

    def _augment(self, obs):
        ids = tuple(self.env.agent_ids)
        n = len(ids)
        return {
            a: jnp.concatenate([obs[a], jax.nn.one_hot(i, n, dtype=obs[a].dtype)])
            for i, a in enumerate(ids)
        }

    def _obs(self, state):
        return self._augment(self.env._obs(state))

    def reset(self, key):
        """Reset the inner env; augment observations with agent ids."""
        state, ts = self.env.reset(key)
        return state, ts._replace(observation=self._augment(ts.observation))

    def step(self, state, actions):
        """Step the inner env; augment observations with agent ids."""
        state, ts = self.env.step(state, actions)
        return state, ts._replace(observation=self._augment(ts.observation))


@dataclasses.dataclass(frozen=True)
class ConcatObsState(Wrapper):
    """Global state = concatenation of every agent's observation.

    For envs whose joint observations carry the full environment state,
    this replaces a hand-rolled ``global_state`` with one shared rule —
    the input centralised critics (MAPPO) and mixers (QMIX) train on.
    Requires the inner env to expose ``_obs(state)`` (all repro envs do).
    """

    def spec(self):
        """The inner spec with the synthesized concat-obs state spec."""
        spec = self.env.spec()
        dim = sum(spec.observations[a].shape[0] for a in spec.agent_ids)
        return dataclasses.replace(spec, state=ArraySpec((dim,)))

    def global_state(self, state):
        """Global state = concatenation of every agent's observation."""
        obs = self.env._obs(state)
        return jnp.concatenate([obs[a] for a in tuple(self.env.agent_ids)])


# ----------------------------------------------------------- stream wrappers


class AutoResetState(NamedTuple):
    """AutoReset wrapper state: next reset key + the inner state."""
    key: Any     # PRNG key consumed by the next auto-reset
    inner: Any   # the wrapped env's state


@dataclasses.dataclass(frozen=True)
class AutoReset(Wrapper):
    """Fused auto-reset: terminated envs restart inside the same `step`.

    When the inner env emits LAST, the state is immediately re-initialised
    from the wrapper's stored key and the returned timestep is *merged*:
    step_type FIRST and the reset observation (the new episode begins),
    but the terminal step's reward and discount (so the ending episode's
    final reward is never lost, and bootstrap terms — which every trainer
    gates on ``discount`` — are correctly zeroed).  The inner LAST is thus
    followed by a FIRST with no host round trip and no wasted step, which
    is what lets a training scan run across episode boundaries.

    Standalone use draws reset randomness from the key stored at `reset`
    (advanced with `fold_in` every step); runners that need reproducible
    streams refresh it each iteration via `replace_reset_keys`.
    """

    def reset(self, key):
        """Reset the inner env and stash the next auto-reset key."""
        inner, ts = self.env.reset(key)
        return AutoResetState(key=jax.random.fold_in(key, 1), inner=inner), ts

    def step(self, state, actions):
        """Step; on LAST, restart in-place and emit the merged FIRST."""
        inner, ts = self.env.step(state.inner, actions)
        reset_inner, reset_ts = self.env.reset(state.key)
        done = ts.last()

        def sel(new, old):
            """Choose the reset value where the episode just terminated."""
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(done, n, o), new, old
            )

        merged = TimeStep(
            step_type=jnp.where(done, reset_ts.step_type, ts.step_type),
            reward=ts.reward,
            discount=ts.discount,
            observation=sel(reset_ts.observation, ts.observation),
        )
        new_state = AutoResetState(
            key=jax.random.fold_in(state.key, 0), inner=sel(reset_inner, inner)
        )
        return new_state, merged

    def global_state(self, state):
        """Delegate to the inner env (unwrapping the AutoReset state)."""
        return self.env.global_state(state.inner)


class EpisodeStatsState(NamedTuple):
    """EpisodeStats wrapper state: running + last-completed stats."""
    inner: Any
    returns: Dict[str, Any]       # running per-agent return, current episode
    length: Any                   # () int32 — steps taken this episode
    last_returns: Dict[str, Any]  # per-agent return of the last completed episode
    last_length: Any


@dataclasses.dataclass(frozen=True)
class EpisodeStats(Wrapper):
    """Accumulate per-agent episode returns/lengths inside the env state.

    An episode completes on a raw LAST, or on the merged FIRST an
    `AutoReset` layer emits at a boundary (whose reward is the terminal
    one) — so the wrapper composes both outside `AutoReset` (fused
    training) and directly over a raw env (the python environment loop).
    Completed-episode stats are published in ``last_returns`` /
    ``last_length`` and persist until the next boundary.
    """

    def _zero_stats(self):
        z = {a: jnp.zeros(()) for a in tuple(self.env.agent_ids)}
        zero_i = jnp.zeros((), jnp.int32)
        return z, zero_i

    def reset(self, key):
        """Reset the inner env with zeroed episode statistics."""
        inner, ts = self.env.reset(key)
        z, zero_i = self._zero_stats()
        return EpisodeStatsState(inner, z, zero_i, dict(z), zero_i), ts

    def step(self, state, actions):
        """Step; accumulate returns/lengths, publish them at boundaries."""
        inner, ts = self.env.step(state.inner, actions)
        completed = ts.last() | ts.first()
        ret = {a: state.returns[a] + ts.reward[a] for a in state.returns}
        length = state.length + 1
        new_state = EpisodeStatsState(
            inner=inner,
            returns={a: jnp.where(completed, 0.0, ret[a]) for a in ret},
            length=jnp.where(completed, 0, length),
            last_returns={
                a: jnp.where(completed, ret[a], state.last_returns[a]) for a in ret
            },
            last_length=jnp.where(completed, length, state.last_length),
        )
        return new_state, ts

    def global_state(self, state):
        """Delegate to the inner env (unwrapping the stats state)."""
        return self.env.global_state(state.inner)


def replace_reset_keys(state, keys):
    """Swap the `AutoReset` key wherever it sits in a wrapper-state stack.

    Runners use this to drive auto-reset randomness from their own key
    stream (one fresh key per env copy per iteration), making training
    a reproducible function of the runner key alone.
    """
    if isinstance(state, AutoResetState):
        return state._replace(key=keys)
    if hasattr(state, "inner") and hasattr(state, "_replace"):
        return state._replace(inner=replace_reset_keys(state.inner, keys))
    raise TypeError("state stack contains no AutoReset layer")
