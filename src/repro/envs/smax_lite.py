"""SMAX-lite: a minimal SMAC-style micromanagement battle in pure JAX.

N allied marines (MARL-controlled) vs N enemy marines driven by the classic
SMAC heuristic (move toward & attack nearest living ally). Units have hp,
a move speed and an attack range/damage. Ally actions: noop / 4 moves /
attack_j for each enemy j (SMAC's target-id action space). Reward (shared):
damage dealt + kill bonus + win bonus, scaled — the dense SMAC shaping.

This is the stand-in for the paper's StarCraft "3m" experiments (VDN vs
independent MADQN) since real SC2 is unavailable offline.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import (
    ArraySpec,
    DiscreteSpec,
    EnvSpec,
    agent_ids,
    restart,
    transition,
)

_MOVES = jnp.array([[0.0, 0.0], [1.0, 0.0], [-1.0, 0.0], [0.0, 1.0], [0.0, -1.0]])


class SmaxState(NamedTuple):
    """SMAX-lite env state (unit positions, healths, cooldowns)."""
    t: jnp.ndarray
    ally_pos: jnp.ndarray    # (N,2)
    ally_hp: jnp.ndarray     # (N,)
    enemy_pos: jnp.ndarray   # (N,2)
    enemy_hp: jnp.ndarray    # (N,)


@dataclasses.dataclass(frozen=True)
class SmaxLite:
    """SMAC-style micro-battle: N allies vs scripted enemies."""
    num_agents: int = 3
    horizon: int = 50
    max_hp: float = 45.0
    attack_range: float = 0.6
    damage: float = 6.0
    move_step: float = 0.15
    arena: float = 2.0

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return agent_ids(self.num_agents)

    @property
    def num_actions(self):
        """Number of discrete actions per agent."""
        return 5 + self.num_agents  # noop + 4 moves + attack each enemy

    def obs_dim(self) -> int:
        """Per-agent observation vector length."""
        n = self.num_agents
        # own (pos 2, hp 1) + allies (n-1)*(rel 2, hp 1) + enemies n*(rel 2, hp 1)
        return 3 + (n - 1) * 3 + n * 3

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={a: ArraySpec((self.obs_dim(),)) for a in self.agent_ids},
            actions={a: DiscreteSpec(self.num_actions) for a in self.agent_ids},
            state=ArraySpec((self.num_agents * 6,)),
        )

    def _obs(self, state: SmaxState):
        n = self.num_agents
        out = {}
        ally_alive = state.ally_hp > 0
        for i, a in enumerate(self.agent_ids):
            own = jnp.concatenate(
                [state.ally_pos[i], state.ally_hp[i][None] / self.max_hp]
            )
            feats = [own]
            for j in range(n):
                if j == i:
                    continue
                rel = (state.ally_pos[j] - state.ally_pos[i]) * ally_alive[j]
                feats.append(
                    jnp.concatenate([rel, (state.ally_hp[j] / self.max_hp)[None]])
                )
            for j in range(n):
                alive = state.enemy_hp[j] > 0
                rel = (state.enemy_pos[j] - state.ally_pos[i]) * alive
                feats.append(
                    jnp.concatenate([rel, (state.enemy_hp[j] / self.max_hp)[None]])
                )
            out[a] = jnp.concatenate(feats) * ally_alive[i]
        return out

    def global_state(self, state: SmaxState):
        """The global state vector (centralised training input)."""
        return jnp.concatenate(
            [
                state.ally_pos.reshape(-1),
                state.ally_hp / self.max_hp,
                state.enemy_pos.reshape(-1),
                state.enemy_hp / self.max_hp,
            ]
        )

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        n = self.num_agents
        k1, k2 = jax.random.split(key)
        ally = jax.random.uniform(k1, (n, 2), minval=-1.0, maxval=-0.5)
        enemy = jax.random.uniform(k2, (n, 2), minval=0.5, maxval=1.0)
        state = SmaxState(
            t=jnp.zeros((), jnp.int32),
            ally_pos=ally,
            ally_hp=jnp.full((n,), self.max_hp),
            enemy_pos=enemy,
            enemy_hp=jnp.full((n,), self.max_hp),
        )
        return state, restart(self.agent_ids, self._obs(state))

    def step(self, state: SmaxState, actions):
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        n = self.num_agents
        acts = jnp.stack([actions[a] for a in self.agent_ids])  # (N,)
        ally_alive = state.ally_hp > 0
        enemy_alive = state.enemy_hp > 0

        # --- ally moves
        move_idx = jnp.clip(acts, 0, 4)
        is_move = acts < 5
        delta = _MOVES[move_idx] * self.move_step * is_move[:, None]
        ally_pos = jnp.clip(
            state.ally_pos + delta * ally_alive[:, None], -self.arena, self.arena
        )

        # --- ally attacks: action 5+j targets enemy j
        target = jnp.clip(acts - 5, 0, n - 1)
        attacks = (acts >= 5) & ally_alive
        dist = jnp.linalg.norm(
            ally_pos - state.enemy_pos[target], axis=-1
        )
        in_range = dist <= self.attack_range
        hit = attacks & in_range & enemy_alive[target]
        dmg_to_enemy = jnp.zeros((n,)).at[target].add(self.damage * hit)
        enemy_hp = jnp.maximum(state.enemy_hp - dmg_to_enemy, 0.0)
        killed = (state.enemy_hp > 0) & (enemy_hp <= 0)

        # --- enemy heuristic: move toward / attack nearest living ally
        d_e2a = jnp.linalg.norm(
            state.enemy_pos[:, None] - ally_pos[None], axis=-1
        )  # (E,A)
        d_e2a = jnp.where(ally_alive[None], d_e2a, 1e9)
        nearest = jnp.argmin(d_e2a, axis=-1)
        nd = jnp.take_along_axis(d_e2a, nearest[:, None], axis=-1)[:, 0]
        can_attack = (nd <= self.attack_range) & enemy_alive
        dmg_to_ally = jnp.zeros((n,)).at[nearest].add(
            self.damage * can_attack * (nd < 1e8)
        )
        ally_hp = jnp.maximum(state.ally_hp - dmg_to_ally, 0.0)
        dir_ = ally_pos[nearest] - state.enemy_pos
        norm = jnp.linalg.norm(dir_, axis=-1, keepdims=True) + 1e-9
        enemy_pos = jnp.where(
            (can_attack | ~enemy_alive)[:, None],
            state.enemy_pos,
            jnp.clip(
                state.enemy_pos + dir_ / norm * self.move_step,
                -self.arena,
                self.arena,
            ),
        )

        t = state.t + 1
        new_state = SmaxState(
            t=t,
            ally_pos=ally_pos,
            ally_hp=ally_hp,
            enemy_pos=enemy_pos,
            enemy_hp=enemy_hp,
        )

        all_enemies_dead = jnp.all(enemy_hp <= 0)
        all_allies_dead = jnp.all(ally_hp <= 0)
        done = all_enemies_dead | all_allies_dead | (t >= self.horizon)
        # SMAC-style dense reward: damage + 10*kill + 200*win, scaled by max
        max_ret = (self.max_hp + 10.0) * n + 200.0
        r = (
            jnp.sum(dmg_to_enemy)
            + 10.0 * jnp.sum(killed)
            + 200.0 * all_enemies_dead
        ) / max_ret * 20.0
        return new_state, transition(self.agent_ids, r, self._obs(new_state), done)
