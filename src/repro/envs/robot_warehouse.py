"""Robot Warehouse (RWARE-lite) in pure JAX — the paper's flagship gridworld.

N robots navigate a warehouse of static shelf racks.  A rotating subset of
shelves is *requested*: a robot on a requested shelf's rack cell can load
it (action 5), carry it to the goal cell and — on arrival — deliver it for
a sparse shared team reward of +1.  Delivered shelves snap back to their
rack and a fresh request is sampled, keeping ``num_requests`` outstanding
(the lite stand-in for RWARE's return-trip: pickup → delivery → new
request).  Robots collide: contested moves are cancelled (one robot per
cell), and a loaded robot cannot pass under an occupied rack.

Actions: 0 noop, 1..4 cardinal moves, 5 load (pickup only — no drop;
a loaded shelf is shed by delivering it at the goal).  Reward is sparse
and shared — the hard-exploration regime the original RWARE benchmarks
probe.  Global state and agent-id observation features come from the
wrapper stack (`AgentIdObs` + `ConcatObsState`), not per-env code.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import DiscreteSpec, ArraySpec, EnvSpec, agent_ids, restart, transition
from repro.envs.grid import apply_moves, hits_cells, resolve_collisions


class RwareState(NamedTuple):
    """RWARE-lite env state (robot poses, loads, outstanding requests)."""
    t: jnp.ndarray          # () int32
    pos: jnp.ndarray        # (N, 2) int32 robot cells
    carrying: jnp.ndarray   # (N,) int32 shelf index, -1 = unloaded
    requested: jnp.ndarray  # (S,) bool
    key: jnp.ndarray        # PRNG for replacement request sampling


@dataclasses.dataclass(frozen=True)
class RobotWarehouse:
    """RWARE-lite: robots ferry requested shelves to goals for +1."""
    num_agents: int = 2
    grid_size: int = 8
    num_shelves: int = 8
    num_requests: int = 2
    horizon: int = 64

    def __post_init__(self):
        if self.num_requests > self.num_shelves:
            raise ValueError("num_requests cannot exceed num_shelves")
        if len(self._shelf_cells()) < self.num_shelves:
            raise ValueError(
                f"grid_size {self.grid_size} fits only "
                f"{len(self._shelf_cells())} shelves, not {self.num_shelves}"
            )

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return agent_ids(self.num_agents)

    @property
    def num_actions(self):
        """Number of discrete actions per agent."""
        return 6  # noop + 4 moves + load

    def _shelf_cells(self):
        """Static rack layout: shelf rows every other row, aisles around."""
        cells = [
            (r, c)
            for r in range(2, self.grid_size - 2, 2)
            for c in range(1, self.grid_size - 1)
        ]
        return cells[: self.num_shelves]

    @property
    def shelf_pos(self):
        """The static (num_shelves, 2) rack layout."""
        return jnp.asarray(self._shelf_cells(), jnp.int32)

    def _goal_cell(self):
        return (self.grid_size - 1, self.grid_size // 2)

    @property
    def goal_pos(self):
        """The static (num_goals, 2) delivery cells."""
        return jnp.asarray(self._goal_cell(), jnp.int32)

    @property
    def _free_cells(self):
        """Spawnable cells: not a rack, not the goal."""
        taken = set(self._shelf_cells()) | {self._goal_cell()}
        free = [
            (r, c)
            for r in range(self.grid_size)
            for c in range(self.grid_size)
            if (r, c) not in taken
        ]
        return jnp.asarray(free, jnp.int32)

    def obs_dim(self) -> int:
        # own pos(2) + carrying(1) + rel goal(2)
        # + per shelf: rel(2) + requested(1) + present(1)
        # + per other agent: rel(2)
        """Per-agent observation vector length."""
        return 5 + 4 * self.num_shelves + 2 * (self.num_agents - 1)

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        obs = ArraySpec((self.obs_dim(),))
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={a: obs for a in self.agent_ids},
            actions={a: DiscreteSpec(self.num_actions) for a in self.agent_ids},
            # the registry wraps this env in ConcatObsState, which overrides
            # the global state with the concat-of-observations rule
            state=ArraySpec((0,)),
        )

    def _present(self, carrying):
        """Which shelves are at their rack (not loaded on a robot)."""
        return ~(
            (carrying[:, None] == jnp.arange(self.num_shelves)[None, :]).any(0)
        )

    def _obs(self, state: RwareState):
        scale = float(self.grid_size - 1)
        present = self._present(state.carrying)
        out = {}
        for i, a in enumerate(self.agent_ids):
            own = state.pos[i].astype(jnp.float32) / scale
            loaded = (state.carrying[i] >= 0).astype(jnp.float32)[None]
            goal_rel = (self.goal_pos - state.pos[i]).astype(jnp.float32) / scale
            shelf_rel = (self.shelf_pos - state.pos[i]).astype(jnp.float32) / scale
            shelf_feats = jnp.concatenate(
                [
                    shelf_rel.reshape(-1),
                    state.requested.astype(jnp.float32),
                    present.astype(jnp.float32),
                ]
            )
            others = jnp.delete(state.pos, i, axis=0, assume_unique_indices=True)
            others_rel = (others - state.pos[i]).astype(jnp.float32) / scale
            out[a] = jnp.concatenate(
                [own, loaded, goal_rel, shelf_feats, others_rel.reshape(-1)]
            )
        return out

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        k_pos, k_req, k_state = jax.random.split(key, 3)
        free = self._free_cells
        idx = jax.random.permutation(k_pos, free.shape[0])[: self.num_agents]
        req_idx = jax.random.permutation(k_req, self.num_shelves)[: self.num_requests]
        state = RwareState(
            t=jnp.zeros((), jnp.int32),
            pos=free[idx],
            carrying=jnp.full((self.num_agents,), -1, jnp.int32),
            requested=jnp.zeros((self.num_shelves,), bool).at[req_idx].set(True),
            key=k_state,
        )
        return state, restart(self.agent_ids, self._obs(state))

    def step(self, state: RwareState, actions):
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        acts = jnp.stack([actions[a] for a in self.agent_ids])  # (N,)
        present = self._present(state.carrying)

        # --- movement: loaded robots cannot pass under an occupied rack
        proposed = apply_moves(state.pos, acts, self.grid_size)
        blocked = hits_cells(proposed, self.shelf_pos, present) & (
            state.carrying >= 0
        )
        pos = resolve_collisions(state.pos, proposed, blocked)

        # --- load: pick the requested, present shelf under the robot
        on_shelf = jnp.all(pos[:, None] == self.shelf_pos[None, :], axis=-1)
        pickable = on_shelf & (present & state.requested)[None, :]
        can_pick = (acts == 5) & (state.carrying < 0) & pickable.any(-1)
        carrying = jnp.where(
            can_pick, jnp.argmax(pickable, axis=-1), state.carrying
        )

        # --- delivery: a loaded robot on the goal cell scores (at most one
        # robot can occupy the goal, so deliveries never contend)
        deliver = jnp.all(pos == self.goal_pos, axis=-1) & (carrying >= 0)
        shelf_ids = jnp.arange(self.num_shelves)
        requested = state.requested & ~(
            (shelf_ids[None, :] == carrying[:, None]) & deliver[:, None]
        ).any(0)
        carrying = jnp.where(deliver, -1, carrying)

        # --- replacement requests keep num_requests outstanding
        key, k_new = jax.random.split(state.key)

        def draw(carry, i):
            """Resample a request uniformly over the shelves."""
            req, k = carry
            k, kk = jax.random.split(k)
            logits = jnp.where(req, -1e9, 0.0)  # uniform over unrequested
            j = jax.random.categorical(kk, logits)
            req = jnp.where(deliver[i], req.at[j].set(True), req)
            return (req, k), None

        (requested, _), _ = jax.lax.scan(
            draw, (requested, k_new), jnp.arange(self.num_agents)
        )

        t = state.t + 1
        new_state = RwareState(
            t=t, pos=pos, carrying=carrying, requested=requested, key=key
        )
        r = jnp.sum(deliver.astype(jnp.float32))  # sparse team reward
        done = t >= self.horizon
        return new_state, transition(
            self.agent_ids, r, self._obs(new_state), done
        )
