"""Level-Based Foraging (Albrecht & Ramamoorthy) in pure JAX.

N leveled agents forage F leveled foods on a grid.  Agents adjacent to a
food that choose ``load`` collect it iff the sum of their levels reaches
the food's level — foods can be leveled above any single agent, forcing
co-location and simultaneous loading (the coordination probe the LBF
benchmarks are built around).

Two reward regimes (the per-agent + team axes of the original suite):

* ``shared_reward=False`` (default): each participating agent is paid its
  level-proportional share of the food's level, normalised by the total
  food level so a perfect episode sums to 1 across the team;
* ``shared_reward=True``: every agent receives the team mean — the fully
  cooperative regime the value-decomposition systems assume.

Actions: 0 noop, 1..4 cardinal moves, 5 load.  Episodes end when every
food is collected or at ``horizon``.  Global state and agent-id features
come from the wrapper stack (`AgentIdObs` + `ConcatObsState`).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import DiscreteSpec, ArraySpec, EnvSpec, agent_ids, restart, transition
from repro.envs.grid import apply_moves, hits_cells, resolve_collisions, sample_distinct_cells


class LbfState(NamedTuple):
    """Level-Based Foraging env state (positions, levels, food)."""
    t: jnp.ndarray            # () int32
    pos: jnp.ndarray          # (N, 2) int32
    levels: jnp.ndarray       # (N,) int32 agent levels (static per episode)
    food_pos: jnp.ndarray     # (F, 2) int32
    food_level: jnp.ndarray   # (F,) int32
    food_active: jnp.ndarray  # (F,) bool


@dataclasses.dataclass(frozen=True)
class LevelBasedForaging:
    """Level-Based Foraging: leveled agents pool to collect leveled food."""
    num_agents: int = 2
    grid_size: int = 8
    num_food: int = 3
    max_level: int = 2
    horizon: int = 32
    shared_reward: bool = False

    def __post_init__(self):
        if self.num_agents + self.num_food > self.grid_size**2:
            raise ValueError("grid too small for agents + food")

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return agent_ids(self.num_agents)

    @property
    def num_actions(self):
        """Number of discrete actions per agent."""
        return 6  # noop + 4 moves + load

    def obs_dim(self) -> int:
        # own pos(2) + own level(1)
        # + per food: rel(2) + level(1) + active(1)
        # + per other agent: rel(2) + level(1)
        """Per-agent observation vector length."""
        return 3 + 4 * self.num_food + 3 * (self.num_agents - 1)

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        obs = ArraySpec((self.obs_dim(),))
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={a: obs for a in self.agent_ids},
            actions={a: DiscreteSpec(self.num_actions) for a in self.agent_ids},
            # overridden by the registry's ConcatObsState wrapper
            state=ArraySpec((0,)),
        )

    def _obs(self, state: LbfState):
        scale = float(self.grid_size - 1)
        lvl_scale = float(self.num_agents * self.max_level)
        out = {}
        for i, a in enumerate(self.agent_ids):
            own = state.pos[i].astype(jnp.float32) / scale
            own_lvl = (state.levels[i].astype(jnp.float32) / self.max_level)[None]
            food_rel = (state.food_pos - state.pos[i]).astype(jnp.float32) / scale
            food_feats = jnp.concatenate(
                [
                    food_rel.reshape(-1),
                    state.food_level.astype(jnp.float32) / lvl_scale,
                    state.food_active.astype(jnp.float32),
                ]
            )
            others = jnp.delete(state.pos, i, axis=0, assume_unique_indices=True)
            other_lvl = jnp.delete(
                state.levels, i, axis=0, assume_unique_indices=True
            )
            other_feats = jnp.concatenate(
                [
                    ((others - state.pos[i]).astype(jnp.float32) / scale).reshape(-1),
                    other_lvl.astype(jnp.float32) / self.max_level,
                ]
            )
            out[a] = jnp.concatenate([own, own_lvl, food_feats, other_feats])
        return out

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        k_cells, k_al, k_fl = jax.random.split(key, 3)
        cells = sample_distinct_cells(
            k_cells, self.grid_size, self.num_agents + self.num_food
        )
        levels = jax.random.randint(
            k_al, (self.num_agents,), 1, self.max_level + 1
        )
        # every food is collectible by the full team acting together
        food_level = jax.random.randint(
            k_fl, (self.num_food,), 1, jnp.sum(levels) + 1
        )
        state = LbfState(
            t=jnp.zeros((), jnp.int32),
            pos=cells[: self.num_agents],
            levels=levels,
            food_pos=cells[self.num_agents :],
            food_level=food_level,
            food_active=jnp.ones((self.num_food,), bool),
        )
        return state, restart(self.agent_ids, self._obs(state))

    def step(self, state: LbfState, actions):
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        acts = jnp.stack([actions[a] for a in self.agent_ids])  # (N,)

        # --- movement: food cells are solid
        proposed = apply_moves(state.pos, acts, self.grid_size)
        blocked = hits_cells(proposed, state.food_pos, state.food_active)
        pos = resolve_collisions(state.pos, proposed, blocked)

        # --- loading: adjacent loaders pool their levels per food
        adjacent = (
            jnp.abs(pos[:, None] - state.food_pos[None, :]).sum(-1) == 1
        )  # (N, F)
        loading = (acts == 5)[:, None] & adjacent & state.food_active[None, :]
        pooled = (state.levels[:, None] * loading).sum(0)  # (F,)
        collected = state.food_active & (pooled >= state.food_level) & (pooled > 0)

        # level-proportional shares, normalised by the total food level
        total_level = jnp.sum(state.food_level).astype(jnp.float32)
        share = (
            loading * state.levels[:, None].astype(jnp.float32)
        ) / jnp.clip(pooled, 1, None)[None, :].astype(jnp.float32)
        gains = (collected * state.food_level).astype(jnp.float32)
        r_agents = (share * gains[None, :]).sum(1) / total_level  # (N,)
        if self.shared_reward:
            r_agents = jnp.full_like(r_agents, jnp.mean(r_agents))
        reward = {a: r_agents[i] for i, a in enumerate(self.agent_ids)}

        food_active = state.food_active & ~collected
        t = state.t + 1
        new_state = LbfState(
            t=t,
            pos=pos,
            levels=state.levels,
            food_pos=state.food_pos,
            food_level=state.food_level,
            food_active=food_active,
        )
        done = (t >= self.horizon) | ~food_active.any()
        return new_state, transition(
            self.agent_ids, reward, self._obs(new_state), done
        )
