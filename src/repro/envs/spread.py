"""MPE simple-spread (Lowe et al. 2017) in pure JAX.

N agents must cover N landmarks. Shared reward = -sum over landmarks of the
distance to the closest agent, minus a collision penalty. Supports discrete
actions (5: noop/right/left/up/down — the PettingZoo default) or continuous
2D forces (for MADDPG/MAD4PG).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import (
    ArraySpec,
    DiscreteSpec,
    EnvSpec,
    agent_ids,
    restart,
    transition,
)

_DIRS = jnp.array([[0.0, 0.0], [1.0, 0.0], [-1.0, 0.0], [0.0, 1.0], [0.0, -1.0]])


class SpreadState(NamedTuple):
    """Spread env state (agent poses/velocities, landmark positions)."""
    t: jnp.ndarray
    pos: jnp.ndarray        # (N,2)
    vel: jnp.ndarray        # (N,2)
    landmarks: jnp.ndarray  # (N,2)


@dataclasses.dataclass(frozen=True)
class Spread:
    """MPE simple-spread: cover all landmarks, avoid collisions."""
    num_agents: int = 3
    horizon: int = 25
    continuous: bool = False
    dt: float = 0.1
    damping: float = 0.25
    accel: float = 5.0
    collision_radius: float = 0.15

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return agent_ids(self.num_agents)

    def obs_dim(self) -> int:
        # own pos(2) + vel(2) + rel landmarks (2N) + rel other agents (2(N-1))
        """Per-agent observation vector length."""
        return 4 + 2 * self.num_agents + 2 * (self.num_agents - 1)

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        obs = ArraySpec((self.obs_dim(),))
        if self.continuous:
            act = ArraySpec((2,))
        else:
            act = DiscreteSpec(5)
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={a: obs for a in self.agent_ids},
            actions={a: act for a in self.agent_ids},
            state=ArraySpec((4 * self.num_agents + 2 * self.num_agents,)),
        )

    def _obs(self, state: SpreadState):
        out = {}
        for i, a in enumerate(self.agent_ids):
            rel_lm = (state.landmarks - state.pos[i]).reshape(-1)
            others = jnp.delete(
                state.pos, i, axis=0, assume_unique_indices=True
            )
            rel_ag = (others - state.pos[i]).reshape(-1)
            out[a] = jnp.concatenate([state.pos[i], state.vel[i], rel_lm, rel_ag])
        return out

    def global_state(self, state: SpreadState):
        """The global state vector (centralised training input)."""
        return jnp.concatenate(
            [state.pos.reshape(-1), state.vel.reshape(-1), state.landmarks.reshape(-1)]
        )

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        k1, k2 = jax.random.split(key)
        pos = jax.random.uniform(k1, (self.num_agents, 2), minval=-1.0, maxval=1.0)
        lm = jax.random.uniform(k2, (self.num_agents, 2), minval=-1.0, maxval=1.0)
        state = SpreadState(
            t=jnp.zeros((), jnp.int32), pos=pos, vel=jnp.zeros_like(pos), landmarks=lm
        )
        return state, restart(self.agent_ids, self._obs(state))

    def _forces(self, actions):
        fs = []
        for a in self.agent_ids:
            act = actions[a]
            if self.continuous:
                fs.append(jnp.clip(act, -1.0, 1.0))
            else:
                fs.append(_DIRS[act])
        return jnp.stack(fs)  # (N,2)

    def step(self, state: SpreadState, actions):
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        f = self._forces(actions) * self.accel
        vel = state.vel * (1.0 - self.damping) + f * self.dt
        pos = jnp.clip(state.pos + vel * self.dt, -1.5, 1.5)
        t = state.t + 1

        # reward: -sum_l min_a dist(l, a) - collisions
        d = jnp.linalg.norm(pos[:, None] - state.landmarks[None], axis=-1)  # (A,L)
        cover = -jnp.sum(jnp.min(d, axis=0))
        dag = jnp.linalg.norm(pos[:, None] - pos[None], axis=-1)
        coll = (dag < self.collision_radius) & (
            ~jnp.eye(self.num_agents, dtype=bool)
        )
        collision_pen = jnp.sum(coll) / 2.0
        r = cover - collision_pen

        new_state = SpreadState(t=t, pos=pos, vel=vel, landmarks=state.landmarks)
        done = t >= self.horizon
        return new_state, transition(self.agent_ids, r, self._obs(new_state), done)
