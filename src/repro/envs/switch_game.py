"""The switch riddle (Foerster et al. 2016) — the paper's communication probe.

N prisoners; each day one (uniformly random) is taken to the interrogation
room, where they see a light switch they may toggle (via the message bit in
communicating systems). Each agent can act: None (0) or Tell (1). On Tell the
episode ends with shared reward +1 if every agent has visited the room,
else -1. Max episode length 4N - 6 (as in the original paper).

Observations per agent: [in_room, day/T]. Communication (switch state) is
delivered by the system's communication module as an extra input; the env
itself exposes `has_been` in the global state for centralised training.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import (
    ArraySpec,
    DiscreteSpec,
    EnvSpec,
    agent_ids,
    restart,
    transition,
)


class SwitchState(NamedTuple):
    """Switch-riddle env state (visit order, day, switch bit)."""
    t: jnp.ndarray           # day
    in_room: jnp.ndarray     # (N,) one-hot: who is in the room today
    has_been: jnp.ndarray    # (N,) bool
    key: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SwitchGame:
    """Foerster's switch riddle: Tell correctly (+1) or wrongly (-1)."""
    num_agents: int = 3

    @property
    def horizon(self):
        """Episode length in steps."""
        return max(4 * self.num_agents - 6, 4)

    @property
    def agent_ids(self):
        """The tuple of agent-id strings."""
        return agent_ids(self.num_agents)

    def spec(self) -> EnvSpec:
        """The env's `EnvSpec` (per-agent obs/action specs + global state)."""
        obs = ArraySpec((2,))
        return EnvSpec(
            agent_ids=self.agent_ids,
            observations={a: obs for a in self.agent_ids},
            actions={a: DiscreteSpec(2) for a in self.agent_ids},
            state=ArraySpec((2 * self.num_agents + 1,)),
        )

    def _obs(self, state: SwitchState):
        frac = state.t.astype(jnp.float32) / self.horizon
        return {
            a: jnp.stack([state.in_room[i].astype(jnp.float32), frac])
            for i, a in enumerate(self.agent_ids)
        }

    def global_state(self, state: SwitchState):
        """The global state vector (centralised training input)."""
        return jnp.concatenate(
            [
                state.in_room.astype(jnp.float32),
                state.has_been.astype(jnp.float32),
                (state.t.astype(jnp.float32) / self.horizon)[None],
            ]
        )

    def reset(self, key):
        """Start a new episode: ``key -> (state, FIRST timestep)``."""
        key, sub = jax.random.split(key)
        first = jax.random.randint(sub, (), 0, self.num_agents)
        in_room = jax.nn.one_hot(first, self.num_agents)
        state = SwitchState(
            t=jnp.zeros((), jnp.int32),
            in_room=in_room,
            has_been=in_room > 0,
            key=key,
        )
        return state, restart(self.agent_ids, self._obs(state))

    def step(self, state: SwitchState, actions):
        # Tell only counts for the agent in the room.
        """Advance one step: ``(state, actions) -> (new_state, timestep)``."""
        acts = jnp.stack([actions[a] for a in self.agent_ids])  # (N,)
        tell = jnp.sum(acts * state.in_room.astype(acts.dtype)) > 0
        all_visited = jnp.all(state.has_been)
        reward = jnp.where(tell, jnp.where(all_visited, 1.0, -1.0), 0.0)

        key, sub = jax.random.split(state.key)
        nxt = jax.random.randint(sub, (), 0, self.num_agents)
        in_room = jax.nn.one_hot(nxt, self.num_agents)
        t = state.t + 1
        new_state = SwitchState(
            t=t,
            in_room=in_room,
            has_been=state.has_been | (in_room > 0),
            key=key,
        )
        done = tell | (t >= self.horizon)
        return new_state, transition(
            self.agent_ids, reward, self._obs(new_state), done
        )
