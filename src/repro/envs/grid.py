"""Shared gridworld mechanics for the cooperative warehouse/foraging envs.

Integer (row, col) grids with cardinal moves, one-pass collision
resolution and distinct-cell spawning — all pure jnp so the envs built on
them stay vmap-able and scannable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# action 0 = noop, 1..4 = up / down / left / right (row, col deltas)
MOVES = jnp.array(
    [[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]], jnp.int32
)


def apply_moves(pos, actions, grid_size: int):
    """Proposed positions: actions 1..4 move one cell, anything else stays."""
    is_move = (actions >= 1) & (actions <= 4)
    idx = jnp.where(is_move, actions, 0)
    return jnp.clip(pos + MOVES[idx], 0, grid_size - 1)


def hits_cells(proposed, cells, mask):
    """For each agent, whether its proposed cell is one of `cells[mask]`."""
    hit = jnp.all(proposed[:, None] == cells[None, :], axis=-1) & mask[None, :]
    return hit.any(-1)


def resolve_collisions(pos, proposed, blocked=None):
    """One-pass conservative collision resolution.

    A move is cancelled when its target is (a) another agent's current
    cell, (b) another agent's proposed cell, or (c) statically `blocked`.
    Cancelling all contested moves in one pass keeps the no-two-agents-
    per-cell invariant without iterating: surviving movers go to cells
    that were empty and uncontested, cancelled agents keep their own
    (distinct) cells.  (Conservative: an agent cannot enter a cell being
    vacated this same step.)
    """
    n = pos.shape[0]
    eye = jnp.eye(n, dtype=bool)
    same_prop = jnp.all(proposed[:, None] == proposed[None, :], axis=-1) & ~eye
    into_cur = jnp.all(proposed[:, None] == pos[None, :], axis=-1) & ~eye
    conflict = same_prop.any(-1) | into_cur.any(-1)
    if blocked is not None:
        conflict = conflict | blocked
    return jnp.where(conflict[:, None], pos, proposed)


def sample_distinct_cells(key, grid_size: int, n: int):
    """`n` distinct (row, col) cells via a permutation of the flat grid."""
    flat = jax.random.permutation(key, grid_size * grid_size)[:n]
    return jnp.stack([flat // grid_size, flat % grid_size], axis=-1).astype(jnp.int32)
