"""Llama-3.1-405B — GQA dense, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab=128256,

    sharding="fsdp_tp",
    source="arXiv:2407.21783",
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=1024,
    vocab=512,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2407.21783",
)
