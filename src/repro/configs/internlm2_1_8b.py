"""InternLM2-1.8B — GQA dense [arXiv:2403.17297]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab=92544,

    source="arXiv:2403.17297",
)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab=512,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2403.17297",
)
