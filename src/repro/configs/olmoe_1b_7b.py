"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    num_experts=64,
    top_k=8,

    source="arXiv:2409.02060",
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    arch_type="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=512,
    num_experts=4,
    top_k=2,
    capacity_factor=2.0,  # no-drop capacity: deterministic smoke/consistency tests
    moe_group_size=64,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2409.02060",
)
