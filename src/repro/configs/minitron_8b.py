"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab=256000,

    source="arXiv:2407.14679",
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab=512,
    attn_window=64,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2407.14679",
)
