"""MusicGen-Large — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv codec (mel frontend) is a stub per the assignment:
input_specs() provides token ids for 4 codebooks directly. The delay-pattern
interleaving utility lives in repro.models.audio.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    num_codebooks=4,

    source="arXiv:2306.05284",
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    arch_type="audio",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab=64,
    num_codebooks=4,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2306.05284",
)
