"""Zamba2-2.7B — mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    mamba_version=2,
    ssm_chunk=128,
    attn_every=6,   # shared attention block after every 6 mamba2 layers
    shared_attn=True,
    attn_window=4096,  # shared blocks use a window so long_500k stays sub-quadratic
    source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    arch_type="hybrid",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=32,
    mamba_version=2,
    ssm_chunk=16,
    attn_every=2,
    shared_attn=True,
    attn_window=32,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2411.15242",
)
