"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 (paper-table config)
[arXiv:2501.kimi2]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,  # per-expert hidden dim (assignment table)
    vocab=163840,
    num_experts=384,
    top_k=8,

    sharding="fsdp_tp",
    source="arXiv:2501.kimi2",
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke",
    arch_type="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab=512,
    num_experts=4,
    top_k=2,
    capacity_factor=2.0,  # no-drop capacity: deterministic smoke/consistency tests
    moe_group_size=64,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2501.kimi2",
)
