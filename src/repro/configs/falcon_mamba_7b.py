"""Falcon-Mamba-7B — attention-free mamba1 SSM [arXiv:2410.05355]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    mamba_version=1,
    ssm_chunk=128,
    source="arXiv:2410.05355",
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke",
    arch_type="ssm",
    num_layers=2,
    d_model=128,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    mamba_version=1,
    ssm_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2410.05355",
)
