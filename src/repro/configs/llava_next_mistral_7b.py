"""LLaVA-NeXT (Mistral-7B backbone) — anyres tiling VLM
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower (CLIP ViT-L/336 + 2-layer MLP projector) is a stub per the
assignment: input_specs() provides precomputed patch embeddings of shape
(B, vision_tokens, d_model). vision_tokens = 2880 = 5 tiles x 576 patches
(base image + 2x2 anyres grid).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    vision_tokens=2880,

    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    arch_type="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab=512,
    vision_tokens=16,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
