"""Granite-8B-Code — llama-arch dense for code [arXiv:2405.04324]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab=49152,

    source="arXiv:2405.04324",
)

SMOKE = ModelConfig(
    name="granite-8b-smoke",
    arch_type="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab=512,
    attn_chunk=16,
    xent_chunk=16,
    dtype="float32",
    source="arXiv:2405.04324",
)
