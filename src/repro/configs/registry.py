"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib
from typing import Tuple

ARCH_IDS: Tuple[str, ...] = (
    "minitron-8b",
    "llava-next-mistral-7b",
    "internlm2-1.8b",
    "olmoe-1b-7b",
    "kimi-k2-1t-a32b",
    "granite-8b",
    "falcon-mamba-7b",
    "zamba2-2.7b",
    "musicgen-large",
    "llama3-405b",
)


def _module(arch_id: str):
    name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    """Reduced same-family variant for CPU smoke tests."""
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).SMOKE
