"""Roofline terms from a compiled dry-run artifact.

compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes / collective_bytes are *global* figures: per-device
costs derived from the SPMD-partitioned HLO text by repro.roofline.hlo_cost
(trip-count-aware — see that module: XLA's built-in cost_analysis() counts
scan bodies once, so it is reported only as a cross-reference), multiplied
by the chip count. Dividing global cost by (chips * per-chip rate) gives the
per-step seconds each resource would need at peak — the three roofline
terms. The largest term is the bottleneck.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.hlo_cost import Cost, module_cost

# re-exported for compatibility with earlier imports
from repro.roofline.hlo_cost import COLLECTIVE_KINDS  # noqa: F401


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    return module_cost(hlo_text).collectives


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: Dict[str, float]
    model_flops_global: float  # 6 * N_active * tokens (x3 for fwd+bwd)
    xla_cost_flops: Optional[float] = None  # raw cost_analysis (scan-undercounted)
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_term(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_term(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_term(self) -> float:
        return sum(self.collective_bytes_per_device.values()) / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_term,
            "memory": self.memory_term,
            "collective": self.collective_term,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/attention/capacity waste detector."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "chips": self.chips,
            "compute_s": self.compute_term,
            "memory_s": self.memory_term,
            "collective_s": self.collective_term,
            "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "hlo_flops_global": self.flops_per_device * self.chips,
            "hlo_bytes_global": self.bytes_per_device * self.chips,
            "useful_ratio": self.useful_flops_ratio,
            "collectives_per_device": dict(self.collective_bytes_per_device),
            "xla_cost_flops_per_device": self.xla_cost_flops,
        }


def roofline_terms(
    arch: str,
    shape: str,
    chips: int,
    cost_analysis: dict,
    hlo_text: str,
    model_flops_global: float,
) -> RooflineReport:
    cost: Cost = module_cost(hlo_text)
    return RooflineReport(
        arch=arch,
        shape=shape,
        chips=chips,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.collectives,
        model_flops_global=model_flops_global,
        xla_cost_flops=float(cost_analysis.get("flops", 0.0)) if cost_analysis else None,
    )
