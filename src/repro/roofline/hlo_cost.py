"""Trip-count-aware cost analysis over optimized HLO text.

XLA's HloCostAnalysis (what compiled.cost_analysis() reports) visits a
while-loop body ONCE, so a model lowered with lax.scan over layers
under-reports FLOPs/bytes/collective traffic by the trip count. This module
re-derives costs from compiled.as_text() with a call-graph walk that scales
while bodies by their trip counts (XLA annotates jax scans with
backend_config known_trip_count).

Counted per instruction (per-device, post-SPMD shapes):
  flops       — dot ops: 2 * prod(result dims) * prod(lhs contracting dims)
                (dots inside fusions included); convolutions approximated the
                same way
  bytes       — operands + result of top-level instructions; a fusion counts
                as one op (internal traffic ignored), matching XLA's
                fusion accounting
  collectives — result bytes per kind (all-gather / all-reduce /
                reduce-scatter / all-to-all / collective-permute)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# "%region_0.2 (arg: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {"
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{$")
# result type = lazily-matched text between "=" and the opcode token right
# before "(". Tuple types may contain /*index=N*/ comments and layout braces.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\((.*)$"
)
_ARG_RE = re.compile(r"%([\w.\-]+)")


def _parse_dims(type_str: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> float:
    total = 0
    for dtype, dims in _parse_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return float(total)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in COLLECTIVE_KINDS:
            self.collectives[k] += other.collectives[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(
            flops=self.flops * m,
            bytes=self.bytes * m,
            collectives={k: v * m for k, v in self.collectives.items()},
        )

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    args: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    symbols: Dict[str, str]  # instr name -> result type


def parse_module(hlo_text: str):
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        stripped = raw.strip()
        if current is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and "->" in stripped:
                current = Computation(m.group(2), [], {})
                if m.group(1):
                    entry_name = m.group(2)
            continue
        if stripped == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(stripped)
        if m:
            name, rtype, op, rest = m.groups()
            arg_str = rest.split(")", 1)[0]
            args = _ARG_RE.findall(arg_str)
            ins = Instruction(name, rtype, op, args, stripped)
            current.instructions.append(ins)
            current.symbols[name] = rtype
    return comps, entry_name


def _dot_flops(instr: Instruction, symbols) -> float:
    res_elems = 1
    dims_list = _parse_dims(instr.result_type)
    if dims_list:
        for d in dims_list[0][1]:
            res_elems *= d
    lhs_type = symbols.get(instr.args[0], "") if instr.args else ""
    lhs_dims_list = _parse_dims(lhs_type)
    if not lhs_dims_list:
        return 0.0
    lhs_dims = lhs_dims_list[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * res_elems * contract


def _trip_count(instr: Instruction, comps) -> int:
    m = re.search(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)', instr.line)
    if m:
        return int(m.group(1))
    # fallback: largest constant in the condition computation
    m = re.search(r"condition=%?([\w.\-]+)", instr.line)
    if m and m.group(1) in comps:
        consts = [
            int(c)
            for ins in comps[m.group(1)].instructions
            for c in re.findall(r"constant\((\d+)\)", ins.line)
        ]
        if consts:
            return max(consts)
    return 1


def _called_comps(line: str) -> List[str]:
    names = []
    for attr in ("calls", "body", "condition", "to_apply", "branch_computations"):
        m = re.search(attr + r"=\{?([%\w.\-, ]+)\}?", line)
        if m:
            for tok in m.group(1).split(","):
                tok = tok.strip().lstrip("%")
                if tok:
                    names.append(tok)
    return names


def _nested_dot_flops(comp: Computation, comps, seen) -> float:
    if comp.name in seen:
        return 0.0
    seen = seen | {comp.name}
    total = 0.0
    for ins in comp.instructions:
        if ins.op == "dot":
            total += _dot_flops(ins, comp.symbols)
        elif ins.op in ("fusion", "call", "custom-call"):
            for sub in _called_comps(ins.line):
                if sub in comps:
                    total += _nested_dot_flops(comps[sub], comps, seen)
    return total


def _instr_bytes(instr: Instruction, symbols) -> float:
    total = _shape_bytes(instr.result_type)
    for a in instr.args:
        total += _shape_bytes(symbols.get(a, ""))
    return total


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota",
}


def computation_cost(comp: Computation, comps, memo) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()  # cycle guard
    cost = Cost()
    for ins in comp.instructions:
        if ins.op == "while":
            trip = _trip_count(ins, comps)
            for attr, mult in (("body", trip), ("condition", trip)):
                m = re.search(attr + r"=%?([\w.\-]+)", ins.line)
                if m and m.group(1) in comps:
                    cost += computation_cost(comps[m.group(1)], comps, memo).scaled(
                        mult
                    )
            continue
        if ins.op == "conditional":
            subs = _called_comps(ins.line)
            branch_costs = [
                computation_cost(comps[s], comps, memo) for s in subs if s in comps
            ]
            if branch_costs:
                cost += max(branch_costs, key=lambda c: c.flops + c.bytes)
            continue
        if ins.op == "fusion":
            for s in _called_comps(ins.line):
                if s in comps:
                    cost.flops += _nested_dot_flops(comps[s], comps, set())
                    # collectives never live inside fusions; bytes: fusion
                    # boundary traffic only
            cost.bytes += _instr_bytes(ins, comp.symbols)
            continue
        if ins.op in ("call", "custom-call"):
            for s in _called_comps(ins.line):
                if s in comps:
                    cost += computation_cost(comps[s], comps, memo)
            cost.bytes += _instr_bytes(ins, comp.symbols)
            continue
        if ins.op == "dot":
            cost.flops += _dot_flops(ins, comp.symbols)
            cost.bytes += _instr_bytes(ins, comp.symbols)
            continue
        base = None
        for c in COLLECTIVE_KINDS:
            if ins.op == c or ins.op.startswith(c + "-"):
                base = c
                break
        if base:
            if not ins.op.endswith("-done"):  # avoid double-count of async pairs
                cost.collectives[base] += _shape_bytes(ins.result_type)
                cost.bytes += _instr_bytes(ins, comp.symbols)
            continue
        if ins.op not in _SKIP_BYTES_OPS:
            cost.bytes += _instr_bytes(ins, comp.symbols)
    memo[comp.name] = cost
    return cost


def module_cost(hlo_text: str) -> Cost:
    comps, entry_name = parse_module(hlo_text)
    if entry_name is None or entry_name not in comps:
        return Cost()
    return computation_cost(comps[entry_name], comps, {})
