from repro.data.tokens import SyntheticTokenDataset, make_lm_batch
from repro.data.trajectory import batch_trajectories

__all__ = ["SyntheticTokenDataset", "make_lm_batch", "batch_trajectories"]
