"""Synthetic LM token pipeline.

No corpora ship offline, so the data layer generates deterministic synthetic
token streams with a Zipfian unigram distribution plus a learnable bigram
structure (token t+1 depends on token t through a fixed permutation with
noise). The structure matters: a model trained on it shows a real, decreasing
loss curve, which the end-to-end example (`examples/lm_train.py`) asserts.

The pipeline mirrors a production host-loader: an iterator of process-local
numpy shards plus `make_lm_batch` that places the global batch on the mesh
using jax.make_array_from_process_local_data semantics (single-process here,
so placement is a device_put with the batch sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticTokenDataset:
    vocab: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    structure: float = 0.8  # prob. that next token follows the bigram rule

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # fixed random permutation defines the bigram rule  t -> perm[t]
        self.perm = rng.permutation(self.vocab)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        self.unigram = probs / probs.sum()

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            yield self.sample(rng)

    def sample(self, rng: np.random.Generator) -> dict:
        b, s = self.batch_size, self.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(self.vocab, size=b, p=self.unigram)
        follow = rng.random((b, s)) < self.structure
        noise = rng.choice(self.vocab, size=(b, s), p=self.unigram)
        for t in range(s):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }


def make_lm_batch(
    host_batch: dict,
    sharding: Optional[jax.sharding.NamedSharding] = None,
):
    """Place a host-side numpy batch onto the mesh with the batch sharding."""
    if sharding is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, host_batch)
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), host_batch
    )
