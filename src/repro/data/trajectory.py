"""Utilities for batching MARL trajectories (host-side analysis only).

On-device trajectory storage lives in repro.core.buffer; these helpers are
for converting rollouts to numpy for plotting / evaluation summaries.
"""
from __future__ import annotations

import jax
import numpy as np


def batch_trajectories(trajs):
    """Stack a list of trajectory pytrees along a leading axis (numpy)."""
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs, axis=0), *trajs)


def episode_returns(rewards: np.ndarray, dones: np.ndarray) -> np.ndarray:
    """Split a flat (T,) reward stream into per-episode returns using dones."""
    returns, acc = [], 0.0
    for r, d in zip(rewards, dones):
        acc += float(r)
        if d:
            returns.append(acc)
            acc = 0.0
    return np.asarray(returns)
