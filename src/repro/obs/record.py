"""Structured run records under ``results/runs/<run_id>/``.

A run record is the marl-jax-style per-run artifact: one directory per
launch holding ``run.json`` (config, provenance, timing splits, final
metrics, optional profile/roofline summaries) next to the metric stream
(``metrics.jsonl`` / ``metrics.csv`` from the logger sinks) and any
profiler trace.  The schema is pinned in `repro.bench.schema.
check_run_record` and validated in CI by ``scripts/check_bench_schema.py``
— the same discipline as the BENCH_* artifacts, so a regression report
can always cite *what ran, where, and how long each part took*.

`provenance()` is also the shared source of the provenance block the
BENCH_eval/BENCH_speed emitters attach to their artifacts.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import time
import uuid
from typing import Any, Dict, Mapping, Optional

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]


def git_sha(repo_root=None) -> str:
    """The repo's current commit sha, or ``"unknown"`` outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=str(repo_root or _REPO_ROOT),
            capture_output=True,
            text=True,
            timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def provenance() -> Dict[str, Any]:
    """Where/when/on-what a measurement ran — the reproducibility block.

    Attached to every run record and (as the ``provenance`` top-level key)
    to BENCH_eval.json / BENCH_speed.json, so any number in an artifact can
    be traced to a commit, a jax version and a device kind.
    """
    import jax

    dev = jax.devices()[0]
    return {
        "git_sha": git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "num_devices": int(jax.local_device_count()),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def default_run_id(tag: str = "run") -> str:
    """A sortable, collision-safe id: ``<tag>-<utc time>-<hex>``."""
    stamp = time.strftime("%Y%m%d-%H%M%S", time.gmtime())
    return f"{tag}-{stamp}-{uuid.uuid4().hex[:6]}"


class RunRecord:
    """One launch's structured artifact directory.

        record = RunRecord("results/runs", config=vars(args), tag="ippo")
        logger = MultiLogger(ConsoleSink(),
                             JsonlSink(record.metrics_path("jsonl")),
                             CsvSink(record.metrics_path("csv")))
        ... train ...
        record.update("timing", total_seconds=wall, compile_seconds=c)
        record.save()

    The document always carries ``run_id``/``provenance``/``config``/
    ``timing``/``metrics``; sections grow via `update` and land in
    ``<dir>/run.json`` on `save`.
    """

    def __init__(
        self,
        root="results/runs",
        run_id: Optional[str] = None,
        config: Optional[Mapping[str, Any]] = None,
        tag: str = "run",
    ):
        self.run_id = run_id or default_run_id(tag)
        self.dir = pathlib.Path(root) / self.run_id
        self.dir.mkdir(parents=True, exist_ok=True)
        self.doc: Dict[str, Any] = {
            "run_id": self.run_id,
            "provenance": provenance(),
            "config": dict(config or {}),
            "timing": {},
            "metrics": {},
        }

    @property
    def path(self) -> pathlib.Path:
        """Where `save` writes the record document."""
        return self.dir / "run.json"

    def metrics_path(self, fmt: str) -> pathlib.Path:
        """The conventional location of the ``fmt`` metric stream."""
        return self.dir / f"metrics.{fmt}"

    def update(self, section: str, **fields: Any) -> None:
        """Merge ``fields`` into a (possibly new) top-level dict section."""
        self.doc.setdefault(section, {}).update(fields)

    def save(self) -> pathlib.Path:
        """Write ``run.json`` (the schema-checked document) and return it."""
        with open(self.path, "w") as f:
            json.dump(self.doc, f, indent=2, default=str)
        return self.path
