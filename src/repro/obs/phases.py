"""Per-phase timing from the runners' existing phase split.

The fused runners already factor one training iteration into the rollout
phase (`repro.core.system._step_phase`: act + env step + observe) and the
update phase (`_do_updates`: the gated trainer updates) — the same split
the seed-vmap update gate relies on.  A fused scan cannot be timed from
the host per phase, so the run record instead carries a *micro-benchmark*
of each phase at the run's exact operating point: each phase jitted alone
and timed warm (best-of, compile excluded), the same discipline as
`repro.bench.throughput`.

The buffer contents never affect a phase's compute (shapes are static;
`update` runs the same program on a fresh buffer as on a full one), so
timing from a freshly initialised state is representative of steady state.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax


def _best_of(fn, *args, repeats: int = 3) -> float:
    """Best-of-``repeats`` seconds per warm call (first call compiles)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_phase_timing(
    system,
    num_envs: int,
    key,
    eval_episodes: int = 0,
    eval_num_envs: Optional[int] = None,
    repeats: int = 3,
) -> Dict[str, float]:
    """Seconds per phase for one iteration of ``system`` at ``num_envs``.

    Returns ``{"rollout_seconds", "update_seconds"}`` — one `_step_phase`
    call and one gated `_do_updates` block respectively — plus
    ``"eval_seconds"`` (one fused evaluator call) when ``eval_episodes``
    is set.  These are the run record's ``timing.phases`` block: the
    honest phase-level answer to "where does an iteration go?" that the
    ROADMAP's kernel/async work needs before attacking the slow phase.
    """
    from repro.core.system import (
        _do_updates,
        _step_phase,
        _training_env,
        init_system_state,
    )

    tenv = _training_env(system.env)
    k_init, k_iter, k_upd, k_eval = jax.random.split(key, 4)
    st = jax.jit(
        functools.partial(
            init_system_state, system, num_envs=num_envs, train_env=tenv
        )
    )(k_init)

    step = jax.jit(lambda s, k: _step_phase(system, tenv, s, k)[:2])
    update = jax.jit(
        lambda tr, buf, k: _do_updates(system, tr, buf, k)
    )

    out: Dict[str, float] = {
        "rollout_seconds": _best_of(step, st, k_iter, repeats=repeats),
        "update_seconds": _best_of(
            update, st.train, st.buffer, k_upd, repeats=repeats
        ),
    }
    if eval_episodes > 0:
        from repro.eval.evaluator import make_evaluator

        eval_fn = jax.jit(
            make_evaluator(system, eval_episodes, eval_num_envs or num_envs)
        )
        out["eval_seconds"] = _best_of(eval_fn, st.train, k_eval, repeats=repeats)
    return out
