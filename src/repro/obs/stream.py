"""In-flight metric streaming out of a fused training jit.

A fused anakin/shard_map run is one `lax.scan` under one jit: without a
tap it is silent until the final iteration returns, which for a long run
means hours of "is it even training?".  `MetricTap` is the *host* half of
the telemetry tap: the runners call it from inside the scan through
``jax.debug.callback`` every ``log_every`` iterations (see
``make_anakin(..., log_every=, log_callback=)``), and it turns the raw
per-iteration metrics into logger rows with live steps-per-second and
trainer update counts.

The hard invariant — taps are *pure observers* — is structural:
`jax.debug.callback` has no outputs, so nothing the host does can flow
back into the computation, and the runners only add the callback (under a
`lax.cond` on the iteration index) when a tap is installed, leaving the
taps-off program untouched.  ``tests/test_bench.py`` pins taps-on vs
taps-off runs bitwise-identical.

SPS is wall-clock from tap construction, so the first row absorbs
compilation (it is *live* telemetry, not a benchmark — `repro.bench`
owns compile-excluded numbers); later rows approach steady state.
"""
from __future__ import annotations

import time
from typing import Any, Mapping, Optional

import numpy as np

from repro.obs.sinks import Logger


class MetricTap:
    """Host-side receiver for in-jit metric emissions.

    Args:
      logger: any `repro.obs.sinks.Logger` (wrap in `SeedAggregator` for
        seed-vectorized runs so lane axes collapse to mean/min/max).
      log_every: the emission period the runner was configured with —
        recorded so rows can report their iteration index.
      steps_per_iteration: environment steps one scan iteration advances
        (num_envs x num_seeds x num_devices), for the live SPS column.
    """

    def __init__(
        self, logger: Logger, log_every: int, steps_per_iteration: int
    ):
        if log_every <= 0:
            raise ValueError(f"log_every must be positive, got {log_every}")
        self.logger = logger
        self.log_every = log_every
        self.steps_per_iteration = steps_per_iteration
        self.emits = 0
        self._t0: Optional[float] = None
        self.reset_clock()

    def reset_clock(self) -> None:
        """Restart the SPS wall-clock (call right before launching the jit)."""
        self._t0 = time.perf_counter()

    def __call__(self, iteration, updates, metrics: Mapping[str, Any]) -> None:
        """The `jax.debug.callback` target: one emission from inside the scan.

        ``iteration`` is the 0-based scan index, ``updates`` the trainer's
        update counter (possibly a ``(num_seeds,)`` lane batch — forwarded
        as-is so the logger's aggregation policy decides), ``metrics`` the
        runner's per-iteration metric dict for this iteration.
        """
        it = int(np.asarray(iteration))
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        row = {
            "iteration": it + 1,
            "updates": updates,
            "sps": (it + 1) * self.steps_per_iteration / elapsed,
        }
        row.update(metrics)
        self.emits += 1
        self.logger.write(row, step=it + 1)
