"""Profiler hooks: trace capture, retrace counting, HLO-cost summaries.

Three ways to see *why* a fused run is slow, all attached to the run
record rather than printed and lost:

  * `profile_trace(dir)` — a context manager around ``jax.profiler.trace``
    writing a TensorBoard/Perfetto trace directory (degrades to a no-op
    with a recorded reason when the profiler cannot start, so ``--profile``
    never kills a training run).
  * `RetraceCounter` — accidental recompiles surface as telemetry, not
    mystery slowness: jax emits `jax.monitoring` duration events per
    jaxpr trace / backend compile, and the counter snapshots them around a
    region.  A steady-state region that re-traces is a bug (shape drift,
    non-hashable static args); the total compile seconds also give the
    run record its compile-vs-steady-state wall split.
  * `roofline_summary(hlo_text)` — the `repro.roofline` trip-count-aware
    cost of a compiled program (FLOPs / bytes / collective traffic), the
    per-program companion to the profiler's timeline.

jax.monitoring offers no per-listener unregister, so one module-level
listener pair is installed on first use and counters are read by
snapshot-delta — cheap enough to leave on for the life of the process.
"""
from __future__ import annotations

import collections
import contextlib
import pathlib
from typing import Any, Dict

import jax

from repro.roofline.hlo_cost import module_cost

TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
MLIR_LOWER_EVENT = "/jax/core/compile/jaxpr_to_mlir_module_duration"
# the stages summed into compile_seconds: lowering + backend compilation.
# jaxpr tracing is excluded on purpose — trace events nest (an outer jit's
# trace contains its inner jits'), so summing them double-counts wall time.
_COMPILE_STAGE_EVENTS = (MLIR_LOWER_EVENT, BACKEND_COMPILE_EVENT)

_EVENT_COUNTS: collections.Counter = collections.Counter()
_EVENT_SECONDS: Dict[str, float] = collections.defaultdict(float)
_INSTALLED = False


def _install() -> None:
    global _INSTALLED
    if _INSTALLED:
        return

    def on_event(event: str, **kwargs: Any) -> None:
        _EVENT_COUNTS[event] += 1

    def on_duration(event: str, duration_secs: float, **kwargs: Any) -> None:
        _EVENT_COUNTS[event] += 1
        _EVENT_SECONDS[event] += float(duration_secs)

    jax.monitoring.register_event_listener(on_event)
    jax.monitoring.register_event_duration_secs_listener(on_duration)
    _INSTALLED = True


class RetraceCounter:
    """Count traces/compiles (and their seconds) inside a ``with`` region.

        with RetraceCounter() as rc:
            out = program(key)
        rc.jaxpr_traces, rc.backend_compiles, rc.compile_seconds

    Re-enterable: each ``with`` takes fresh snapshots.  ``summary()`` is
    the dict the run record stores under ``"retrace"``.
    """

    def __enter__(self) -> "RetraceCounter":
        _install()
        self._counts0 = dict(_EVENT_COUNTS)
        self._secs0 = dict(_EVENT_SECONDS)
        return self

    def __exit__(self, *exc) -> None:
        self.jaxpr_traces = _EVENT_COUNTS[TRACE_EVENT] - self._counts0.get(
            TRACE_EVENT, 0
        )
        self.backend_compiles = _EVENT_COUNTS[
            BACKEND_COMPILE_EVENT
        ] - self._counts0.get(BACKEND_COMPILE_EVENT, 0)
        self.compile_seconds = sum(
            _EVENT_SECONDS[event] - self._secs0.get(event, 0.0)
            for event in _COMPILE_STAGE_EVENTS
        )

    def summary(self) -> Dict[str, float]:
        """The run-record ``retrace`` block (call after the region exits)."""
        return {
            "jaxpr_traces": int(self.jaxpr_traces),
            "backend_compiles": int(self.backend_compiles),
            "compile_seconds": float(self.compile_seconds),
        }


@contextlib.contextmanager
def profile_trace(out_dir):
    """Capture a ``jax.profiler.trace`` into ``out_dir`` around the body.

    Yields a dict describing the capture (``{"trace_dir": ...}``, plus a
    ``"skipped"`` reason when the profiler could not start); the body runs
    either way, so profiling can never take down the run it observes.
    """
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    info: Dict[str, Any] = {"trace_dir": str(out)}
    ctx = None
    try:
        ctx = jax.profiler.trace(str(out))
        ctx.__enter__()
    except Exception as e:  # profiler backends vary by install
        ctx = None
        info["skipped"] = f"{type(e).__name__}: {e}"
    try:
        yield info
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)


def roofline_summary(hlo_text: str) -> Dict[str, Any]:
    """The `repro.roofline` HLO-cost block for a compiled program.

    Trip-count-aware (scan bodies scaled by their trip counts — see
    `repro.roofline.hlo_cost`), so the figures cover the *whole* fused
    training run, not one loop body.
    """
    cost = module_cost(hlo_text)
    return {
        "hlo_flops": float(cost.flops),
        "hlo_bytes": float(cost.bytes),
        "collective_bytes": float(cost.collective_bytes),
        "collectives": {k: float(v) for k, v in cost.collectives.items()},
    }
