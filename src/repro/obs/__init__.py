"""``repro.obs`` — streaming telemetry, run records and profiler hooks.

The observability subsystem: what a run is doing *while it runs* (the
in-jit `MetricTap` + logger sinks), what it did once it finished (the
structured `RunRecord` under ``results/runs/<run_id>/``), and why it was
slow (`profile_trace` / `RetraceCounter` / `roofline_summary`).  See
``docs/OBSERVABILITY.md`` for the run-record schema and workflows.
"""
from repro.obs.phases import measure_phase_timing
from repro.obs.profile import (
    RetraceCounter,
    profile_trace,
    roofline_summary,
)
from repro.obs.record import RunRecord, default_run_id, git_sha, provenance
from repro.obs.sinks import (
    ConsoleSink,
    CsvSink,
    JsonlSink,
    Logger,
    MultiLogger,
    SeedAggregator,
    to_python,
)
from repro.obs.stream import MetricTap

__all__ = [
    "ConsoleSink",
    "CsvSink",
    "JsonlSink",
    "Logger",
    "MetricTap",
    "MultiLogger",
    "RetraceCounter",
    "RunRecord",
    "SeedAggregator",
    "default_run_id",
    "git_sha",
    "measure_phase_timing",
    "profile_trace",
    "provenance",
    "roofline_summary",
    "to_python",
]
