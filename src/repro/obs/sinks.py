"""The logger multiplexer: one ``Logger`` protocol, many sinks.

Telemetry producers (the in-jit metric tap, the launchers, the bench
harnesses) write dict-shaped metric rows through a single ``Logger``
interface; where those rows end up — terminal, ``metrics.jsonl``,
``metrics.csv``, several at once — is a composition decision made at
launch time, exactly the Mava logger-stack idiom:

    logger = MultiLogger(ConsoleSink(), JsonlSink(p), CsvSink(p2))
    logger.write({"episode_return": 1.5, "sps": 80_000}, step=128)

`SeedAggregator` wraps any sink for seed-vectorized runs: metric values
arriving with a leading ``(num_seeds,)`` lane axis are reduced to
mean / min / max columns before being forwarded, so a vmapped 8-seed run
logs one human-readable row per tap instead of eight.

Sinks are pure observers of host-side values: they never touch traced
arrays (the tap converts via `jax.debug.callback` first) and never feed
anything back into the computation.
"""
from __future__ import annotations

import csv
import json
import sys
from typing import Any, Dict, Mapping, Optional, Protocol, Sequence

import numpy as np


def to_python(value: Any) -> Any:
    """A JSON/CSV-serialisable python value from any scalar/array leaf."""
    if isinstance(value, (bool, int, float, str)) or value is None:
        return value
    arr = np.asarray(value)
    if arr.ndim == 0:
        if arr.dtype == np.bool_:
            return bool(arr)
        if np.issubdtype(arr.dtype, np.integer):
            return int(arr)
        return float(arr)
    return arr.tolist()


class Logger(Protocol):
    """The sink interface every telemetry consumer implements."""

    def write(self, metrics: Mapping[str, Any], step: Optional[int] = None) -> None:
        """Record one row of named metric values (``step`` orders rows)."""
        ...

    def close(self) -> None:
        """Flush and release any underlying resource (idempotent)."""
        ...


class ConsoleSink:
    """Human-facing terminal sink — the single formatting path for stdout.

    ``write`` renders a metric row as aligned ``key=value`` pairs;
    ``line`` emits free-form text through the same prefix, so launcher
    reporting and streamed telemetry look like one program talking.
    """

    def __init__(self, stream=None, prefix: str = ""):
        self._stream = stream if stream is not None else sys.stdout
        self.prefix = prefix

    @staticmethod
    def _fmt(value: Any) -> str:
        value = to_python(value)
        if isinstance(value, float):
            return f"{value:,.4g}"
        if isinstance(value, list):
            return np.array2string(np.asarray(value), precision=3)
        return str(value)

    def write(self, metrics: Mapping[str, Any], step: Optional[int] = None) -> None:
        parts = [] if step is None else [f"step={step}"]
        parts += [f"{k}={self._fmt(v)}" for k, v in metrics.items()]
        self.line("  ".join(parts))

    def line(self, text: str) -> None:
        """Free-form console output (the launchers' former ``print`` path)."""
        print(f"{self.prefix}{text}", file=self._stream, flush=True)

    def close(self) -> None:
        """Nothing to release — the stream is borrowed, not owned."""


class JsonlSink:
    """One JSON object per row, appended to ``path`` (machine-readable)."""

    def __init__(self, path):
        self.path = path
        self._f = open(path, "a")

    def write(self, metrics: Mapping[str, Any], step: Optional[int] = None) -> None:
        row: Dict[str, Any] = {} if step is None else {"step": int(step)}
        row.update({k: to_python(v) for k, v in metrics.items()})
        self._f.write(json.dumps(row) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class CsvSink:
    """A rectangular CSV of the metric stream.

    The header is pinned by the first row written; later rows may omit
    columns (logged empty) but introducing a *new* key is an error — a
    telemetry stream with a drifting schema is a bug at the producer, and
    failing loudly here beats silently dropping the column.
    """

    def __init__(self, path):
        self.path = path
        self._f = open(path, "a", newline="")
        self._writer: Optional[csv.DictWriter] = None

    def write(self, metrics: Mapping[str, Any], step: Optional[int] = None) -> None:
        row: Dict[str, Any] = {} if step is None else {"step": int(step)}
        row.update({k: to_python(v) for k, v in metrics.items()})
        if self._writer is None:
            self._writer = csv.DictWriter(self._f, fieldnames=list(row))
            self._writer.writeheader()
        unknown = set(row) - set(self._writer.fieldnames)
        if unknown:
            raise ValueError(
                f"CsvSink: keys {sorted(unknown)} not in the header pinned by "
                f"the first row {self._writer.fieldnames}"
            )
        self._writer.writerow(row)
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class MultiLogger:
    """Fan one ``write`` out to several sinks (the multiplexer itself)."""

    def __init__(self, *sinks: Logger):
        self.sinks: Sequence[Logger] = tuple(sinks)

    def write(self, metrics: Mapping[str, Any], step: Optional[int] = None) -> None:
        for s in self.sinks:
            s.write(metrics, step=step)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


class SeedAggregator:
    """Reduce seed-vectorized metric lanes before they reach a sink.

    Values with a leading ``(num_seeds,)`` axis become three columns —
    ``k`` (mean over lanes), ``k/min`` and ``k/max`` — so a vmapped
    multi-seed run streams one row per tap. Scalars pass through
    untouched, which keeps the wrapper safe to leave on for serial runs.
    """

    def __init__(self, inner: Logger):
        self.inner = inner

    def write(self, metrics: Mapping[str, Any], step: Optional[int] = None) -> None:
        out: Dict[str, Any] = {}
        for k, v in metrics.items():
            arr = np.asarray(v)
            if arr.ndim == 0 or isinstance(v, (str, bool)):
                out[k] = v
                continue
            lanes = arr.reshape(arr.shape[0], -1).mean(axis=1)
            out[k] = float(lanes.mean())
            out[f"{k}/min"] = float(lanes.min())
            out[f"{k}/max"] = float(lanes.max())
        self.inner.write(out, step=step)

    def close(self) -> None:
        self.inner.close()
