"""Sharding-aware pytree checkpointing to .npz (no orbax offline).

Pytrees are flattened with '/'-joined key paths. Sharded jax.Arrays are
gathered to host before saving (fine single-process; a multi-host version
would save per-process shards — noted in DESIGN.md). Restore returns numpy
leaves reassembled into the original structure; the caller device_puts them
with the target shardings.
"""
from __future__ import annotations

import os
import re
from typing import Optional

import jax
import numpy as np

_SEP = "/"


def jnp_cast(val, dtype):
    import jax.numpy as jnp

    return jnp.asarray(val).astype(dtype)


def _is_typed_key(leaf) -> bool:
    """True for jax typed PRNG key arrays (key<fry> etc.)."""
    return hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
        leaf.dtype, jax.dtypes.prng_key
    )


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        if _is_typed_key(leaf):  # typed PRNG keys save as raw key data
            leaf = jax.random.key_data(leaf)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz/numpy can't cast bf16; widen
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez appends .npz unless present
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.match(r"ckpt_(\d+)\.npz$", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree):
    """Restore into the structure of `target_tree` (values replaced)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(_path_str(e) for e in p)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        val = data[key]
        if _is_typed_key(leaf):
            # rewrap raw key data into the target's typed-key impl (the
            # one leaf kind that restores as a jax array, not numpy)
            leaves.append(
                jax.random.wrap_key_data(val, impl=jax.random.key_impl(leaf))
            )
            continue
        if hasattr(leaf, "dtype") and val.dtype != leaf.dtype:
            # cast through jnp (numpy has no bf16 cast kernel)
            val = np.asarray(jax.device_get(jnp_cast(val, leaf.dtype)))
        leaves.append(val)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), leaves
    )
