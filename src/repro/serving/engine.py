"""Continuous-batching serving engine (slot-based, single jitted decode).

A fixed pool of `max_slots` generation slots shares one KV cache; requests
are admitted into free slots (a per-request prefill writes the prompt into
the slot's cache region), and one jitted `decode_step` advances *all* live
slots each tick — slots can be at different depths because the cache keeps
**per-stream positions** (see attention_decode). Finished slots (EOS or
max_new_tokens) are freed and refilled from the queue: the continuous-
batching discipline (vLLM-style, minus paging) on a static-shape JAX
program.

Simplifications vs a production server (documented, not hidden):
- prefill runs per admission rather than chunked alongside decode;
- dead slots still consume decode FLOPs (their outputs are discarded) —
  fine at these slot counts, paging would fix it at scale.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_slots: int = 4,
        prompt_capacity: int = 64,
        max_new_tokens: int = 64,
    ):
        if cfg.arch_type in ("vlm", "audio"):
            raise NotImplementedError("engine demo covers token-only archs")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.capacity = prompt_capacity + max_new_tokens
        self.prompt_capacity = prompt_capacity
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.finished: List[Request] = []

        self._decode = jax.jit(lambda p, c, t: M.decode_step(p, c, t, self.cfg))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, b, self.cfg, max_len=self.capacity)
        )
        self.cache = M.init_cache(cfg, max_slots, self.capacity)
        self.last_tokens = np.zeros((max_slots, 1), np.int32)

    # ------------------------------------------------------------- admission

    def submit(self, req: Request):
        assert req.prompt.ndim == 1 and len(req.prompt) <= self.prompt_capacity
        self.queue.append(req)

    def _merge_slot(self, slot: int, one_cache):
        """Copy a single-stream cache into pool slot `slot`.

        Cache leaves have the stream dim at index 1 (kv/conv/ssm are stacked
        (L, B, ...)) except "pos" which is (B,).
        """

        def merge(pool, one):
            if pool.ndim == 1:  # pos (B,)
                return pool.at[slot].set(one[0])
            return pool.at[:, slot].set(one[:, 0])

        self.cache = jax.tree_util.tree_map(merge, self.cache, one_cache)

    def _admit(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.popleft()
            self.slots[slot] = req
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            logits, one_cache = self._prefill(self.params, batch)
            self._merge_slot(slot, one_cache)
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            self.last_tokens[slot, 0] = tok

    # ----------------------------------------------------------------- step

    def step(self) -> Dict[int, int]:
        """Admit, decode one token for all live slots, retire finished."""
        self._admit()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return {}
        toks = jnp.asarray(self.last_tokens)
        logits, self.cache = self._decode(self.params, self.cache, toks)
        next_tokens = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)

        emitted = {}
        for i in live:
            req = self.slots[i]
            tok = int(next_tokens[i])
            req.output.append(tok)
            emitted[req.uid] = tok
            self.last_tokens[i, 0] = tok
            if (req.eos_id is not None and tok == req.eos_id) or len(
                req.output
            ) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.finished
