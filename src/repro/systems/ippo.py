"""IPPO — independent PPO (decentralised critics)."""
from repro.systems.onpolicy import PPOConfig, make_ippo

__all__ = ["make_ippo", "PPOConfig"]
