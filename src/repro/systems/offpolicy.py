"""Generic off-policy value-based MARL builder (MADQN / VDN / QMIX).

One builder covers the whole value-decomposition family: the `mixer`
argument selects independent learners (None — MADQN), additive mixing
(VDN) or monotonic hypernet mixing (QMIX). Double-DQN targets, periodic
hard target sync, epsilon-greedy with a linear schedule, optional parameter
sharing across agents, and optional fingerprint replay stabilisation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.buffer import (
    buffer_add,
    buffer_can_sample,
    buffer_init,
    buffer_sample,
)
from repro.core.modules.stabilisation import FingerPrintStabilisation
from repro.core.system import System
from repro.core.types import TrainState, Transition
from repro.envs.api import EnvSpec
from repro.nn import MLP


@dataclasses.dataclass(frozen=True)
class OffPolicyConfig:
    """Replay-family hyperparameters (nets, replay table, exploration)."""
    hidden_sizes: Sequence[int] = (64, 64)
    learning_rate: float = 5e-4
    gamma: float = 0.99
    buffer_capacity: int = 50_000
    batch_size: int = 64
    min_replay: int = 500
    target_update_period: int = 100
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 10_000
    shared_weights: bool = True
    max_grad_norm: float = 10.0
    fingerprint: bool = False
    distributed_axis: Optional[str] = None  # pmean grads over this mesh axis
    updates_per_step: int = 1


def make_offpolicy_system(env, cfg: OffPolicyConfig, mixer=None, name="madqn") -> System:
    """Build a replay-based Q-learning `System` (MADQN/VDN/QMIX core)."""
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    num_actions = {a: spec.actions[a].num_values for a in ids}
    fp = FingerPrintStabilisation() if cfg.fingerprint else None
    obs_dims = {
        a: spec.observations[a].shape[0] + (fp.size if fp else 0) for a in ids
    }
    state_dim = spec.state.shape[0]

    # one Q-net per agent, or one shared net when homogeneous
    homogeneous = len(set((obs_dims[a], num_actions[a]) for a in ids)) == 1
    share = cfg.shared_weights and homogeneous
    nets = {
        a: MLP((obs_dims[a], *cfg.hidden_sizes, num_actions[a])) for a in ids
    }

    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )

    def init_params(key):
        """Initialise per-agent Q-net (and mixer) parameters."""
        if share:
            return {"shared": nets[ids[0]].init(key)}
        keys = jax.random.split(key, len(ids))
        return {a: nets[a].init(k) for a, k in zip(ids, keys)}

    def q_values(params, agent, obs):
        """Per-agent Q-values for an observation batch."""
        p = params["shared"] if share else params[agent]
        return nets[agent].apply(p, obs)

    def init_train(key) -> TrainState:
        """Initialise the `TrainState` (params, targets, optimizer, steps)."""
        k1, k2 = jax.random.split(key)
        params = {"q": init_params(k1)}
        if mixer is not None:
            params["mixer"] = mixer.init(k2, len(ids), state_dim)
        return TrainState(
            params=params,
            target_params=params,
            opt_state=opt.init(params),
            steps=jnp.zeros((), jnp.int32),
        )

    def eps_at(steps):
        """Linearly-decayed exploration epsilon after ``steps`` updates."""
        frac = jnp.clip(steps / cfg.eps_decay_steps, 0.0, 1.0)
        return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)

    def _augment(obs, train: TrainState):
        if fp is None:
            return obs
        return fp.augment(obs, eps_at(train.steps), train.steps)

    def select_actions(train: TrainState, obs, state, carry, key, training=True):
        """Eps-greedy actions from the per-agent Q-nets."""
        del state  # decentralised execution
        obs = _augment(obs, train)
        eps = eps_at(train.steps) if training else 0.0
        actions = {}
        for i, a in enumerate(ids):
            k_rand, k_explore = jax.random.split(jax.random.fold_in(key, i))
            q = q_values(train.params["q"], a, obs[a])
            greedy = jnp.argmax(q, axis=-1)
            rand = jax.random.randint(k_rand, greedy.shape, 0, num_actions[a])
            explore = jax.random.uniform(k_explore, greedy.shape) < eps
            actions[a] = jnp.where(explore, rand, greedy).astype(jnp.int32)
        return actions, carry, {}

    def initial_carry(batch_shape):
        """The executor's initial memory for a ``batch_shape`` of envs."""
        del batch_shape
        return ()

    # ------------------------------------------------------------- trainer

    def loss_fn(params, target_params, batch: Transition, steps):
        """Double-DQN TD loss (mixed over agents when a mixer is set)."""
        obs = batch.obs
        next_obs = batch.next_obs
        if fp is not None:
            eps = eps_at(steps)
            obs = fp.augment(obs, eps, steps)
            next_obs = fp.augment(next_obs, eps, steps)
        chosen, targets = [], []
        for a in ids:
            q = q_values(params["q"], a, obs[a])  # (B, A)
            qa = jnp.take_along_axis(q, batch.actions[a][:, None], axis=-1)[:, 0]
            # double-DQN target
            q_next_online = q_values(params["q"], a, next_obs[a])
            best = jnp.argmax(q_next_online, axis=-1)
            q_next_target = q_values(target_params["q"], a, next_obs[a])
            qn = jnp.take_along_axis(q_next_target, best[:, None], axis=-1)[:, 0]
            chosen.append(qa)
            targets.append(qn)
        chosen = jnp.stack(chosen, axis=-1)   # (B, N)
        targets = jnp.stack(targets, axis=-1)
        r = jnp.stack([batch.rewards[a] for a in ids], axis=-1)

        if mixer is None:
            td_target = r + cfg.gamma * batch.discount[:, None] * targets
            td = chosen - jax.lax.stop_gradient(td_target)
        else:
            q_tot = mixer.apply(params["mixer"], chosen, batch.state)
            q_tot_next = mixer.apply(
                target_params["mixer"], targets, batch.next_state
            )
            # cooperative: shared reward = mean over agents' rewards
            r_tot = jnp.mean(r, axis=-1)
            td_target = r_tot + cfg.gamma * batch.discount * q_tot_next
            td = q_tot - jax.lax.stop_gradient(td_target)
        return jnp.mean(jnp.square(td))

    def update(train: TrainState, buffer, key):
        """One trainer update: ``(train, buffer, key) -> (train, buffer, metrics)``."""
        batch = buffer_sample(buffer, key, cfg.batch_size)
        loss, grads = jax.value_and_grad(loss_fn)(
            train.params, train.target_params, batch, train.steps
        )
        if cfg.distributed_axis:
            grads = jax.lax.pmean(grads, cfg.distributed_axis)
        updates, opt_state = opt.update(grads, train.opt_state, train.params)
        params = optim.apply_updates(train.params, updates)
        steps = train.steps + 1
        target_params = jax.tree_util.tree_map(
            lambda t, o: jnp.where(steps % cfg.target_update_period == 0, o, t),
            train.target_params,
            params,
        )
        return (
            TrainState(params, target_params, opt_state, steps),
            buffer,
            {"loss": loss, "eps": eps_at(steps)},
        )

    # ------------------------------------------------------------- dataset

    def example_transition():
        """A zero `Transition` fixing the buffer's shapes and dtypes."""
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((), jnp.int32) for a in ids},
            rewards={a: jnp.zeros(()) for a in ids},
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            extras={},
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        """A fresh experience buffer for ``num_envs`` parallel envs."""
        del num_envs  # replay rows are flattened across envs
        return buffer_init(example_transition(), cfg.buffer_capacity)

    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=buffer_add,
        can_sample=lambda buf: buffer_can_sample(buf, cfg.min_replay),
        updates_per_step=cfg.updates_per_step,
        name=name,
    )
