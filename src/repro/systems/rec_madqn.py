"""rec-MADQN — recurrent independent Q-learning over sequence replay (R2D2).

The first *recurrent off-policy* system: per-agent encoder -> memory core
-> Q-head stacks (Kapturowski et al. 2019's R2D2 recipe, one learner per
agent as in independent MADQN), trained from the sequence-replay regime
(`repro.core.buffer.SeqBufferState`) instead of the flat per-step table —
a recurrent value function needs its memory trajectory, so replay stores
fixed-length time-major windows with the executor's incoming `Carry`
riding along per step in ``Transition.extras["carry_in"]`` (the same
protocol rec-IPPO uses).

Each sampled window splits into a **burn-in prefix** and a **training
suffix**: the trainer opens from the *stored* window-start carry
(`window_start_carry` — never the zero start-state approximation), unrolls
the burn-in rows under current online/target params with stopped gradients
(`burn_in_carry`) to wash out parameter staleness, then runs double-DQN TD
over the suffix — online-net argmax, target-net evaluation, in-window
next-Q shift plus one bootstrap step on the final next-observation (gated
by the stored discount at terminals), with memory reset at stored FIRST
rows inside the unroll (`reset_carry` semantics, folded into the cores'
``resets`` argument).

Weights are shared across agents when the env is homogeneous and
``shared_weights`` is set; heterogeneous envs (speaker_listener) get
per-agent stacks, so the system runs on all seven envs.  The update
schedule is data-independent (`seq_can_sample` gates on a pure function of
the step counter), which keeps the seed-vmap runners' hoisted update gate
sound — see docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.buffer import seq_add, seq_can_sample, seq_init, seq_sample
from repro.core.system import System
from repro.core.types import Carry, TrainState, Transition
from repro.envs.api import EnvSpec, StepType
from repro.nn import MLP
from repro.nn.recurrent import burn_in_carry, make_core, window_start_carry


@dataclasses.dataclass(frozen=True)
class RecMadqnConfig:
    """R2D2-style recurrent Q-learning hyperparameters.

    The replay window is ``burn_in + seq_len`` steps: ``burn_in`` rows
    warm the memory with stopped gradients, ``seq_len`` rows take TD
    gradients.  ``stride`` spaces window starts in the incoming step
    stream (None -> ``seq_len``, the R2D2 default: consecutive windows
    overlap by exactly the burn-in prefix, so every transition lands in
    exactly one training suffix).  ``buffer_capacity`` / ``min_windows`` /
    ``batch_size`` count *windows*, not steps.
    """

    hidden_sizes: Sequence[int] = (64,)
    learning_rate: float = 5e-4
    gamma: float = 0.99
    seq_len: int = 8
    burn_in: int = 4
    stride: Optional[int] = None
    buffer_capacity: int = 2048
    batch_size: int = 32
    min_windows: int = 64
    target_update_period: int = 100
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 10_000
    shared_weights: bool = True
    recurrent_core: str = "gru"
    max_grad_norm: float = 10.0
    distributed_axis: Optional[str] = None
    updates_per_step: int = 1


def make_rec_madqn(env, cfg: RecMadqnConfig = RecMadqnConfig()) -> System:
    """Build the recurrent MADQN `System` over sequence replay."""
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    num_actions = {a: spec.actions[a].num_values for a in ids}
    obs_dims = {a: spec.observations[a].shape[0] for a in ids}
    hidden = cfg.hidden_sizes[-1]
    window_len = cfg.burn_in + cfg.seq_len
    stride = cfg.seq_len if cfg.stride is None else cfg.stride
    if cfg.seq_len < 1 or cfg.burn_in < 0 or stride < 1:
        raise ValueError(
            f"need seq_len >= 1, burn_in >= 0, stride >= 1; got "
            f"seq_len={cfg.seq_len}, burn_in={cfg.burn_in}, stride={stride}"
        )

    homogeneous = len(set((obs_dims[a], num_actions[a]) for a in ids)) == 1
    share = cfg.shared_weights and homogeneous

    def stack(in_dim, out_dim):
        """One encoder -> memory core -> Q-head network stack."""
        return {
            "encoder": MLP((in_dim, *cfg.hidden_sizes), activate_final=True),
            "core": make_core(cfg.recurrent_core, hidden, hidden),
            "head": MLP((hidden, out_dim)),
        }

    nets = {a: stack(obs_dims[a], num_actions[a]) for a in ids}

    def init_stack(net, key):
        """Initialise one encoder/core/head stack."""
        ke, kc, kh = jax.random.split(key, 3)
        return {
            "encoder": net["encoder"].init(ke),
            "core": net["core"].init(kc),
            "head": net["head"].init(kh),
        }

    def init_params(key):
        """Per-agent Q-stacks (one shared stack when homogeneous)."""
        if share:
            return {"shared": init_stack(nets[ids[0]], key)}
        keys = jax.random.split(key, len(ids))
        return {a: init_stack(nets[a], k) for a, k in zip(ids, keys)}

    def _p(params, agent):
        return params["shared"] if share else params[agent]

    def q_step(params, agent, h, x):
        """One act-time step: ``(h, obs) -> (h, q_values)``."""
        net, p = nets[agent], _p(params, agent)
        z = net["encoder"].apply(p["encoder"], x)
        h, y = net["core"].step(p["core"], h, z)
        return h, net["head"].apply(p["head"], y)

    def q_unroll(params, agent, h, xs, resets):
        """BPTT over ``(T, B, obs)`` rows with FIRST-row resets."""
        net, p = nets[agent], _p(params, agent)
        z = net["encoder"].apply(p["encoder"], xs)
        h, ys = net["core"].unroll(p["core"], h, z, resets)
        return h, net["head"].apply(p["head"], ys)

    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )

    def init_train(key) -> TrainState:
        """Initialise the `TrainState` (params, targets, optimizer, steps)."""
        params = init_params(key)
        return TrainState(
            params=params,
            target_params=params,
            opt_state=opt.init(params),
            steps=jnp.zeros((), jnp.int32),
        )

    def eps_at(steps):
        """Linearly-decayed exploration epsilon after ``steps`` updates."""
        frac = jnp.clip(steps / cfg.eps_decay_steps, 0.0, 1.0)
        return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)

    # ------------------------------------------------------------ executor

    def initial_carry(batch_shape):
        """The executor's initial memory for a ``batch_shape`` of envs."""
        return Carry(
            hidden={a: jnp.zeros((*batch_shape, hidden)) for a in ids}
        )

    def select_actions(train: TrainState, obs, state, carry, key, training=True):
        """Eps-greedy recurrent act step; the incoming carry rides extras.

        In training mode the *incoming* carry is stored per step in
        ``extras["carry_in"]`` (the runner has already zeroed it at
        auto-reset FIRST boundaries), so sampled replay windows open from
        the exact executor memory instead of the R2D2 zero start-state.
        """
        del state  # decentralised execution
        eps = eps_at(train.steps) if training else 0.0
        actions, new_h = {}, {}
        for i, a in enumerate(ids):
            h, q = q_step(train.params, a, carry.hidden[a], obs[a])
            greedy = jnp.argmax(q, axis=-1)
            k_rand, k_explore = jax.random.split(jax.random.fold_in(key, i))
            rand = jax.random.randint(k_rand, greedy.shape, 0, num_actions[a])
            explore = jax.random.uniform(k_explore, greedy.shape) < eps
            actions[a] = jnp.where(explore, rand, greedy).astype(jnp.int32)
            new_h[a] = h
        extras = {"carry_in": carry} if training else {}
        return actions, Carry(hidden=new_h), extras

    # ------------------------------------------------------------- trainer

    def loss_fn(params, target_params, win: Transition, carry0: Carry):
        """Double-DQN TD over the training suffix of each sampled window.

        ``win`` is time-major ``(window_len, B)``; both online and target
        nets warm their memory over the burn-in prefix from the stored
        window-start carry with stopped gradients, then unroll the suffix.
        Next-step Q's come from the in-window shift plus one bootstrap step
        on the final next-observation; terminal rows are gated by the
        stored discount (a row whose successor opens a new episode carries
        discount 0, so its stale-memory bootstrap never leaks in).
        """
        first = win.step_type == StepType.FIRST  # (window_len, B)
        sl = slice(cfg.burn_in, None)
        total = 0.0
        for a in ids:
            on = lambda h, xs, rs: q_unroll(params, a, h, xs, rs)
            tg = lambda h, xs, rs: q_unroll(target_params, a, h, xs, rs)
            prefix = win.obs[a][: cfg.burn_in]
            h_on = burn_in_carry(on, carry0.hidden[a], prefix, first[: cfg.burn_in])
            h_tg = burn_in_carry(tg, carry0.hidden[a], prefix, first[: cfg.burn_in])
            h_on, q_on = on(h_on, win.obs[a][sl], first[sl])  # (seq_len, B, A)
            h_tg, q_tg = tg(h_tg, win.obs[a][sl], first[sl])
            last_obs = win.next_obs[a][-1]
            _, qb_on = q_step(params, a, h_on, last_obs)
            _, qb_tg = q_step(target_params, a, h_tg, last_obs)
            q_next_on = jnp.concatenate([q_on[1:], qb_on[None]], axis=0)
            q_next_tg = jnp.concatenate([q_tg[1:], qb_tg[None]], axis=0)
            best = jnp.argmax(q_next_on, axis=-1)
            qn = jnp.take_along_axis(q_next_tg, best[..., None], -1)[..., 0]
            qa = jnp.take_along_axis(
                q_on, win.actions[a][sl][..., None], -1
            )[..., 0]
            target = win.rewards[a][sl] + cfg.gamma * win.discount[sl] * qn
            td = qa - jax.lax.stop_gradient(target)
            total = total + jnp.mean(jnp.square(td))
        return total / len(ids)

    def update(train: TrainState, buffer, key):
        """One trainer update: sample windows, TD step, periodic target sync."""
        win = seq_sample(buffer, key, cfg.batch_size)  # leaves (T, B, ...)
        carry0 = window_start_carry(
            win.extras, initial_carry, (cfg.batch_size,)
        )
        win = win._replace(
            extras={k: v for k, v in win.extras.items() if k != "carry_in"}
        )
        loss, grads = jax.value_and_grad(loss_fn)(
            train.params, train.target_params, win, carry0
        )
        if cfg.distributed_axis:
            grads = jax.lax.pmean(grads, cfg.distributed_axis)
        updates, opt_state = opt.update(grads, train.opt_state, train.params)
        params = optim.apply_updates(train.params, updates)
        steps = train.steps + 1
        target_params = jax.tree_util.tree_map(
            lambda t, o: jnp.where(steps % cfg.target_update_period == 0, o, t),
            train.target_params,
            params,
        )
        return (
            TrainState(params, target_params, opt_state, steps),
            buffer,
            {"loss": loss, "eps": eps_at(steps)},
        )

    # ------------------------------------------------------------- dataset

    def example_transition():
        """A zero `Transition` fixing the buffer's shapes and dtypes."""
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((), jnp.int32) for a in ids},
            rewards={a: jnp.zeros(()) for a in ids},
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            # the incoming Carry per step, read back at row 0 of each
            # sampled window (window_start_carry) — the stored-state start
            extras={"carry_in": initial_carry(())},
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        """A fresh sequence-replay buffer for ``num_envs`` parallel envs."""
        return seq_init(
            example_transition(), cfg.buffer_capacity, window_len, num_envs
        )

    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=lambda buf, tr: seq_add(buf, tr, stride=stride),
        can_sample=lambda buf: seq_can_sample(buf, cfg.min_windows),
        updates_per_step=cfg.updates_per_step,
        name="rec_madqn",
    )
