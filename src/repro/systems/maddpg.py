"""MADDPG (Lowe et al. 2017) and MAD4PG (distributional critic, D4PG-style).

Continuous-control actor-critic with centralised critics: each agent's
critic sees the global state and *all* agents' actions (the
CentralisedQValueCritic architecture); execution is decentralised. MAD4PG
replaces the scalar critic with a C51 categorical critic and a projected
distributional Bellman target (Barth-Maron et al. 2018).

The `architecture` argument switches between decentralised / centralised /
networked critics — the paper's Block-4 code change.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.architectures import CentralisedQValueCritic
from repro.core.buffer import (
    buffer_add,
    buffer_can_sample,
    buffer_init,
    buffer_sample,
)
from repro.core.system import System
from repro.core.types import TrainState, Transition
from repro.envs.api import EnvSpec
from repro.nn import MLP


@dataclasses.dataclass(frozen=True)
class MaddpgConfig:
    """MADDPG/MAD4PG hyperparameters (nets, noise, replay, C51 support)."""
    hidden_sizes: Sequence[int] = (64, 64)
    actor_lr: float = 1e-3
    critic_lr: float = 3e-3
    gamma: float = 0.95
    tau: float = 0.01  # polyak
    buffer_capacity: int = 200_000
    batch_size: int = 512
    min_replay: int = 2_000
    sigma: float = 0.15  # exploration noise
    max_grad_norm: float = 10.0
    distributed_axis: Optional[str] = None
    # distributional (MAD4PG) head
    distributional: bool = False
    num_atoms: int = 51
    v_min: float = -150.0
    v_max: float = 20.0


def make_maddpg(env, cfg: MaddpgConfig = MaddpgConfig(), architecture=None) -> System:
    """Build the centralised-critic DDPG `System` (continuous control)."""
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    arch = architecture or CentralisedQValueCritic(agent_order=tuple(ids))
    act_dims = {a: spec.actions[a].shape[0] for a in ids}
    obs_dims = {a: spec.observations[a].shape[0] for a in ids}
    state_dim = spec.state.shape[0]

    actors = {
        a: MLP((obs_dims[a], *cfg.hidden_sizes, act_dims[a])) for a in ids
    }

    def critic_in_dim(a):
        # infer by building a dummy critic input
        """Centralised critic input: global state + every agent's action."""
        obs = {b: jnp.zeros((obs_dims[b],)) for b in ids}
        acts = {b: jnp.zeros((act_dims[b],)) for b in ids}
        gs = jnp.zeros((state_dim,))
        return arch.critic_input(obs, acts, gs, a).shape[-1]

    out_dim = cfg.num_atoms if cfg.distributional else 1
    critics = {a: MLP((critic_in_dim(a), *cfg.hidden_sizes, out_dim)) for a in ids}
    atoms = jnp.linspace(cfg.v_min, cfg.v_max, cfg.num_atoms)

    actor_opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm), optim.adamw(cfg.actor_lr)
    )
    critic_opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm), optim.adamw(cfg.critic_lr)
    )

    def init_train(key):
        """Initialise the `TrainState` (params, targets, optimizer, steps)."""
        ka, kc = jax.random.split(key)
        kas = jax.random.split(ka, len(ids))
        kcs = jax.random.split(kc, len(ids))
        params = {
            "actor": {a: actors[a].init(k) for a, k in zip(ids, kas)},
            "critic": {a: critics[a].init(k) for a, k in zip(ids, kcs)},
        }
        opt_state = {
            "actor": actor_opt.init(params["actor"]),
            "critic": critic_opt.init(params["critic"]),
        }
        return TrainState(params, params, opt_state, jnp.zeros((), jnp.int32))

    def policy(params, agent, obs):
        """The deterministic policy's action for one agent (tanh-squashed)."""
        return jnp.tanh(actors[agent].apply(params["actor"][agent], obs))

    def critic_value(params, agent, obs, acts, gs):
        """The critic's value (scalar or C51 logits) for one agent."""
        cin = arch.critic_input(obs, acts, gs, agent)
        out = critics[agent].apply(params["critic"][agent], cin)
        if cfg.distributional:
            probs = jax.nn.softmax(out, axis=-1)
            return jnp.sum(probs * atoms, axis=-1), out
        return out[..., 0], out

    def select_actions(train, obs, state, carry, key, training=True):
        """Deterministic actions + exploration noise when training."""
        del state  # decentralised execution
        actions = {}
        for i, a in enumerate(ids):
            mu = policy(train.params, a, obs[a])
            if training:
                noise = (
                    jax.random.normal(jax.random.fold_in(key, i), mu.shape)
                    * cfg.sigma
                )
                mu = jnp.clip(mu + noise, -1.0, 1.0)
            actions[a] = mu
        return actions, carry, {}

    def initial_carry(batch_shape):
        """The executor's initial memory for a ``batch_shape`` of envs."""
        del batch_shape
        return ()

    def _project_distribution(target_probs, target_atoms):
        """C51 projection of (B, A) probs at shifted atoms onto fixed atoms."""
        dz = (cfg.v_max - cfg.v_min) / (cfg.num_atoms - 1)
        tz = jnp.clip(target_atoms, cfg.v_min, cfg.v_max)  # (B, A)
        b = (tz - cfg.v_min) / dz
        lo = jnp.floor(b).astype(jnp.int32)
        hi = jnp.ceil(b).astype(jnp.int32)
        eq = (lo == hi).astype(jnp.float32)
        w_lo = target_probs * (hi.astype(jnp.float32) - b + eq)
        w_hi = target_probs * (b - lo.astype(jnp.float32))
        B = target_probs.shape[0]
        out = jnp.zeros((B, cfg.num_atoms))
        bidx = jnp.arange(B)[:, None]
        out = out.at[bidx, lo].add(w_lo)
        out = out.at[bidx, hi].add(w_hi)
        return out

    def critic_loss_fn(cparams, params, target_params, batch: Transition):
        """TD (or C51 cross-entropy) loss against target actions/values."""
        loss = 0.0
        p = dict(params, critic=cparams)
        next_acts = {
            a: policy(target_params, a, batch.next_obs[a]) for a in ids
        }
        for a in ids:
            q, logits = critic_value(
                p, a, batch.obs, batch.actions, batch.state
            )
            qn, next_logits = critic_value(
                target_params, a, batch.next_obs, next_acts, batch.next_state
            )
            r = batch.rewards[a]
            if cfg.distributional:
                target_atoms = (
                    r[:, None] + cfg.gamma * batch.discount[:, None] * atoms[None]
                )
                target_probs = jax.nn.softmax(next_logits, axis=-1)
                proj = jax.lax.stop_gradient(
                    _project_distribution(target_probs, target_atoms)
                )
                logp = jax.nn.log_softmax(logits, axis=-1)
                loss = loss + jnp.mean(-jnp.sum(proj * logp, axis=-1))
            else:
                target = r + cfg.gamma * batch.discount * qn
                loss = loss + jnp.mean(
                    jnp.square(q - jax.lax.stop_gradient(target))
                )
        return loss

    def actor_loss_fn(aparams, params, batch: Transition):
        """Deterministic policy-gradient loss through the frozen critic."""
        loss = 0.0
        p = dict(params, actor=aparams)
        for a in ids:
            acts = {b: batch.actions[b] for b in ids}
            acts[a] = policy(p, a, batch.obs[a])
            q, _ = critic_value(p, a, batch.obs, acts, batch.state)
            loss = loss - jnp.mean(q)
        return loss

    def update(train: TrainState, buffer, key):
        """One trainer update: ``(train, buffer, key) -> (train, buffer, metrics)``."""
        batch = buffer_sample(buffer, key, cfg.batch_size)
        closs, cgrads = jax.value_and_grad(critic_loss_fn)(
            train.params["critic"], train.params, train.target_params, batch
        )
        aloss, agrads = jax.value_and_grad(actor_loss_fn)(
            train.params["actor"], train.params, batch
        )
        if cfg.distributed_axis:
            cgrads = jax.lax.pmean(cgrads, cfg.distributed_axis)
            agrads = jax.lax.pmean(agrads, cfg.distributed_axis)
        cupd, c_opt = critic_opt.update(
            cgrads, train.opt_state["critic"], train.params["critic"]
        )
        aupd, a_opt = actor_opt.update(
            agrads, train.opt_state["actor"], train.params["actor"]
        )
        params = {
            "actor": optim.apply_updates(train.params["actor"], aupd),
            "critic": optim.apply_updates(train.params["critic"], cupd),
        }
        target_params = jax.tree_util.tree_map(
            lambda t, o: (1 - cfg.tau) * t + cfg.tau * o, train.target_params, params
        )
        return (
            TrainState(
                params, target_params, {"actor": a_opt, "critic": c_opt},
                train.steps + 1,
            ),
            buffer,
            {"critic_loss": closs, "actor_loss": aloss},
        )

    def example_transition():
        """A zero `Transition` fixing the buffer's shapes and dtypes."""
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((act_dims[a],)) for a in ids},
            rewards={a: jnp.zeros(()) for a in ids},
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            extras={},
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        """A fresh experience buffer for ``num_envs`` parallel envs."""
        del num_envs  # replay rows are flattened across envs
        return buffer_init(example_transition(), cfg.buffer_capacity)

    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=buffer_add,
        can_sample=lambda buf: buffer_can_sample(buf, cfg.min_replay),
        name="mad4pg" if cfg.distributional else "maddpg",
        action_space="continuous",
    )


def make_mad4pg(env, cfg: MaddpgConfig = MaddpgConfig(), architecture=None) -> System:
    """MADDPG with a C51 distributional critic (the MAD4PG variant)."""
    cfg = dataclasses.replace(cfg, distributional=True)
    return make_maddpg(env, cfg, architecture)
