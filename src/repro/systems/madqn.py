"""MADQN — independent multi-agent DQN (Tampuu et al. 2017).

Optionally stabilised with policy fingerprints (Foerster et al. 2017c) via
``OffPolicyConfig(fingerprint=True)`` — the paper's
``stabilising.FingerPrintStabalisation(architecture)`` wrapper.

This is the feed-forward variant over the flat per-step replay table; the
recurrent variant over R2D2 sequence replay (stored-carry windows with
burn-in) is `repro.systems.rec_madqn.make_rec_madqn`.
"""
from repro.systems.offpolicy import OffPolicyConfig, make_offpolicy_system


def make_madqn(env, cfg: OffPolicyConfig = OffPolicyConfig()):
    """Build independent double-DQN learners (optionally fingerprinted)."""
    return make_offpolicy_system(env, cfg, mixer=None, name="madqn")
