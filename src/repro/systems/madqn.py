"""MADQN — independent multi-agent DQN (Tampuu et al. 2017).

Optionally stabilised with policy fingerprints (Foerster et al. 2017c) via
``OffPolicyConfig(fingerprint=True)`` — the paper's
``stabilising.FingerPrintStabalisation(architecture)`` wrapper.
"""
from repro.systems.offpolicy import OffPolicyConfig, make_offpolicy_system


def make_madqn(env, cfg: OffPolicyConfig = OffPolicyConfig()):
    """Build independent double-DQN learners (optionally fingerprinted)."""
    return make_offpolicy_system(env, cfg, mixer=None, name="madqn")
