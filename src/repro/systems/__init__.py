"""The MARL algorithm families, all behind the one `System` API + registry."""
from repro.systems.madqn import make_madqn
from repro.systems.vdn import make_vdn
from repro.systems.qmix import make_qmix
from repro.systems.ippo import make_ippo
from repro.systems.mappo import make_mappo
from repro.systems.onpolicy import make_rec_ippo, make_rec_mappo
from repro.systems.rec_madqn import make_rec_madqn
from repro.systems.maddpg import make_maddpg, make_mad4pg
from repro.systems.dial import make_dial
from repro.systems.registry import (
    REGISTRY,
    SystemEntry,
    compatibility,
    make_pair,
    make_system,
)

__all__ = [
    "make_madqn",
    "make_vdn",
    "make_qmix",
    "make_ippo",
    "make_mappo",
    "make_rec_ippo",
    "make_rec_mappo",
    "make_rec_madqn",
    "make_maddpg",
    "make_mad4pg",
    "make_dial",
    "REGISTRY",
    "SystemEntry",
    "compatibility",
    "make_pair",
    "make_system",
]
