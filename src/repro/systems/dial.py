"""DIAL — Differentiable Inter-Agent Learning (Foerster et al. 2016).

Recurrent Q-agents with a learned 1-bit channel on the switch riddle (the
paper's Fig. 4 top experiment). Centralised training: the channel is the
continuous DRU (sigmoid + noise), so TD gradients flow *between agents*
through the message; decentralised execution thresholds the message to a
hard bit.

Training is episode-based BPTT: (1) roll out a batch of episodes eps-greedily
with the current params (no gradients); (2) re-run the recurrent nets over
the stored episodes differentiably (same actions, messages recomputed with
gradients) and minimise the TD error of the chosen-action Q's with targets
from the target network.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.modules.communication import BroadcastedCommunication, dru
from repro.core.types import TrainState
from repro.envs.api import StepType
from repro.nn import GRUCell, MLP


@dataclasses.dataclass(frozen=True)
class DialConfig:
    hidden_dim: int = 64
    channel_size: int = 1
    noise_std: float = 0.5
    learning_rate: float = 5e-4
    gamma: float = 1.0
    batch_episodes: int = 32
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_updates: int = 300
    target_update_period: int = 20
    max_grad_norm: float = 10.0
    use_comm: bool = True  # False -> ablation: recurrent independent MADQN
    # "dial": differentiable DRU channel (gradients flow between agents)
    # "rial": discrete message chosen eps-greedily from a message Q-head and
    #         trained by Q-learning (no cross-agent gradients) — the RIAL
    #         baseline from Foerster et al. 2016
    protocol: str = "dial"


class DialNets(NamedTuple):
    encoder: MLP
    core: GRUCell
    q_head: MLP
    msg_head: MLP


def make_dial(env, cfg: DialConfig = DialConfig()):
    spec = env.spec()
    ids = list(spec.agent_ids)
    n = len(ids)
    obs_dim = spec.observations[ids[0]].shape[0]
    num_actions = spec.actions[ids[0]].num_values
    comm = BroadcastedCommunication(cfg.channel_size, cfg.noise_std, shared=True)
    in_dim = obs_dim + (comm.incoming_size(n) if cfg.use_comm else 0)

    rial = cfg.protocol == "rial"
    msg_out = 2 * cfg.channel_size if rial else cfg.channel_size
    nets = DialNets(
        encoder=MLP((in_dim, cfg.hidden_dim), activate_final=True),
        core=GRUCell(cfg.hidden_dim, cfg.hidden_dim),
        q_head=MLP((cfg.hidden_dim, cfg.hidden_dim, num_actions)),
        msg_head=MLP((cfg.hidden_dim, cfg.hidden_dim, msg_out)),
    )
    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )
    horizon = env.horizon

    def init_train(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        params = {
            "encoder": nets.encoder.init(k1),
            "core": nets.core.init(k2),
            "q_head": nets.q_head.init(k3),
            "msg_head": nets.msg_head.init(k4),
        }
        return TrainState(params, params, opt.init(params), jnp.zeros((), jnp.int32))

    def agent_step(params, obs_a, msg_in, h):
        """One recurrent step for one agent (shared weights)."""
        x = jnp.concatenate([obs_a, msg_in], axis=-1) if cfg.use_comm else obs_a
        z = nets.encoder.apply(params["encoder"], x)
        h = nets.core.apply(params["core"], h, z)
        q = nets.q_head.apply(params["q_head"], h)
        m = nets.msg_head.apply(params["msg_head"], h)
        return q, m, h

    def initial_carry(batch_shape):
        h = {a: jnp.zeros((*batch_shape, cfg.hidden_dim)) for a in ids}
        msg = {a: jnp.zeros((*batch_shape, cfg.channel_size)) for a in ids}
        return {"h": h, "msg": msg}

    def eps_at(steps):
        frac = jnp.clip(steps / cfg.eps_decay_updates, 0.0, 1.0)
        return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)

    def rollout(params, steps, key, batch: int, training: bool):
        """Roll a batch of episodes. Returns stacked episode data."""
        k_env, k_run = jax.random.split(key)
        env_state, ts = jax.vmap(env.reset)(jax.random.split(k_env, batch))
        carry0 = initial_carry((batch,))

        def step(c, t):
            env_state, ts, carry, key, alive = c
            key, k_eps, k_dru, k_act = jax.random.split(key, 4)
            incoming = comm.route(carry["msg"]) if cfg.use_comm else None
            actions, new_h, out_msgs, qs = {}, {}, {}, {}
            eps = eps_at(steps) if training else 0.0
            msg_bits = {}
            for i, a in enumerate(ids):
                msg_in = incoming[a] if cfg.use_comm else jnp.zeros((batch, 0))
                q, m, h = agent_step(params, ts.observation[a], msg_in, carry["h"][a])
                greedy = jnp.argmax(q, axis=-1)
                ka = jax.random.fold_in(k_act, i)
                rand = jax.random.randint(ka, greedy.shape, 0, num_actions)
                explore = jax.random.uniform(ka, greedy.shape) < eps
                actions[a] = jnp.where(explore, rand, greedy).astype(jnp.int32)
                if rial:
                    # RIAL: the message bit is an eps-greedy action from the
                    # message Q-head (hard bit in training and execution)
                    qm = m.reshape(batch, cfg.channel_size, 2)
                    bit_greedy = jnp.argmax(qm, axis=-1)
                    km = jax.random.fold_in(k_dru, i)
                    bit_rand = jax.random.randint(km, bit_greedy.shape, 0, 2)
                    bit_explore = jax.random.uniform(km, bit_greedy.shape) < eps
                    bit = jnp.where(bit_explore, bit_rand, bit_greedy).astype(
                        jnp.int32
                    )
                    msg_bits[a] = bit
                    out_msgs[a] = bit.astype(jnp.float32)
                else:
                    # DIAL: DRU (continuous in training, hard bit execution)
                    out_msgs[a] = dru(
                        m, jax.random.fold_in(k_dru, i), cfg.noise_std, training
                    )
                new_h[a] = h
                qs[a] = q
            new_env_state, new_ts = jax.vmap(env.step)(env_state, actions)
            # freeze finished episodes (no reset: fixed-horizon batch)
            done_now = new_ts.step_type == StepType.LAST

            def keep(new, old):
                d = alive.reshape(alive.shape + (1,) * (new.ndim - 1))
                return jnp.where(d, new, old)

            env_state2 = jax.tree_util.tree_map(keep, new_env_state, env_state)
            ts2 = jax.tree_util.tree_map(keep, new_ts, ts)
            reward = jnp.mean(jnp.stack(list(new_ts.reward.values())), axis=0)
            data = dict(
                obs=ts.observation,
                actions=actions,
                reward=reward * alive,
                alive=alive,
                discount=new_ts.discount,
                msgs={a: out_msgs[a] for a in ids},
                msg_bits=msg_bits if rial else {},
            )
            alive2 = alive & ~done_now
            carry2 = {"h": new_h, "msg": out_msgs}
            return (env_state2, ts2, carry2, key, alive2), data

        init = (env_state, ts, carry0, k_run, jnp.ones((batch,), bool))
        (_, _, _, _, _), episode = jax.lax.scan(step, init, jnp.arange(horizon))
        return episode  # leaves: (T, batch, ...)

    def q_trajectory(params, episode, key, training: bool):
        """Differentiable re-run over a stored episode.

        DIAL: messages are recomputed with gradients (the channel is part of
        the computation graph). RIAL: stored hard bits are teacher-forced
        (no cross-agent gradients); returns message Q-values as well.
        Returns (qs, msg_qs) — msg_qs is {} for DIAL.
        """
        batch = episode["reward"].shape[1]
        carry0 = initial_carry((batch,))

        def step(c, data_t):
            carry, key = c
            key, k_dru = jax.random.split(key)
            incoming = comm.route(carry["msg"]) if cfg.use_comm else None
            qs, new_h, out_msgs, msg_qs = {}, {}, {}, {}
            for i, a in enumerate(ids):
                msg_in = incoming[a] if cfg.use_comm else jnp.zeros((batch, 0))
                q, m, h = agent_step(params, data_t["obs"][a], msg_in, carry["h"][a])
                qs[a] = q
                new_h[a] = h
                if rial:
                    msg_qs[a] = m.reshape(batch, cfg.channel_size, 2)
                    out_msgs[a] = data_t["msgs"][a]  # teacher-forced bits
                else:
                    out_msgs[a] = dru(
                        m, jax.random.fold_in(k_dru, i), cfg.noise_std, training
                    )
            return ({"h": new_h, "msg": out_msgs}, key), (qs, msg_qs)

        (_, _), (qs, msg_qs) = jax.lax.scan(step, (carry0, key), episode)
        return qs, msg_qs  # per-agent (T, batch, A) / (T, batch, C, 2)

    def loss_fn(params, target_params, episode, key, steps):
        k1, k2 = jax.random.split(key)
        qs, msg_qs = q_trajectory(params, episode, k1, training=True)
        qs_t, msg_qs_t = q_trajectory(target_params, episode, k2, True)
        qs_target = jax.tree_util.tree_map(jax.lax.stop_gradient, qs_t)
        msg_qs_target = jax.tree_util.tree_map(jax.lax.stop_gradient, msg_qs_t)
        total, count = 0.0, 0.0
        r = episode["reward"]  # (T, B) shared
        d = episode["discount"]
        alive = episode["alive"].astype(jnp.float32)
        for a in ids:
            q = qs[a]  # (T, B, A)
            qa = jnp.take_along_axis(q, episode["actions"][a][..., None], -1)[..., 0]
            q_next_max = jnp.max(qs_target[a][1:], axis=-1)  # (T-1, B)
            target = r[:-1] + cfg.gamma * d[:-1] * q_next_max
            target = jnp.concatenate([target, r[-1][None]], axis=0)
            td = (qa - jax.lax.stop_gradient(target)) * alive
            total = total + jnp.sum(jnp.square(td))
            count = count + jnp.sum(alive)
            if rial:
                # message-bit Q-learning (RIAL trains the channel by TD)
                qm = msg_qs[a]  # (T, B, C, 2)
                bits = episode["msg_bits"][a][..., None]  # (T, B, C, 1)
                qmb = jnp.take_along_axis(qm, bits, -1)[..., 0]  # (T, B, C)
                qm_next = jnp.max(msg_qs_target[a][1:], axis=-1)  # (T-1, B, C)
                tgt = r[:-1, :, None] + cfg.gamma * d[:-1, :, None] * qm_next
                tgt = jnp.concatenate(
                    [tgt, jnp.broadcast_to(r[-1][None, :, None], tgt[:1].shape)],
                    axis=0,
                )
                td_m = (qmb - jax.lax.stop_gradient(tgt)) * alive[..., None]
                total = total + jnp.sum(jnp.square(td_m))
                count = count + jnp.sum(alive) * cfg.channel_size
        return total / jnp.maximum(count, 1.0)

    def update(train: TrainState, key):
        k_roll, k_loss = jax.random.split(key)
        episode = rollout(
            train.params, train.steps, k_roll, cfg.batch_episodes, training=True
        )
        loss, grads = jax.value_and_grad(loss_fn)(
            train.params, train.target_params, episode, k_loss, train.steps
        )
        updates, opt_state = opt.update(grads, train.opt_state, train.params)
        params = optim.apply_updates(train.params, updates)
        steps = train.steps + 1
        target_params = jax.tree_util.tree_map(
            lambda t, o: jnp.where(steps % cfg.target_update_period == 0, o, t),
            train.target_params,
            params,
        )
        mean_ret = jnp.sum(episode["reward"]) / cfg.batch_episodes
        return (
            TrainState(params, target_params, opt_state, steps),
            {"loss": loss, "return": mean_ret},
        )

    def evaluate(train: TrainState, key, batch: int = 128):
        episode = rollout(train.params, train.steps, key, batch, training=False)
        return jnp.sum(episode["reward"]) / batch

    return dict(
        init_train=init_train,
        update=update,
        evaluate=evaluate,
        rollout=rollout,
        name=(cfg.protocol if cfg.use_comm else "rec-madqn"),
    )


def train_dial(env, cfg: DialConfig, key, num_updates: int):
    """Jit-fused DIAL training. Returns (train_state, metrics over updates)."""
    system = make_dial(env, cfg)
    key, k_init = jax.random.split(key)
    train = system["init_train"](k_init)

    @jax.jit
    def run(train, key):
        def body(carry, _):
            train, key = carry
            key, k = jax.random.split(key)
            train, metrics = system["update"](train, k)
            return (train, key), metrics

        return jax.lax.scan(body, (train, key), None, length=num_updates)

    (train, _), metrics = run(train, key)
    return train, metrics, system
