"""DIAL — Differentiable Inter-Agent Learning (Foerster et al. 2016).

Recurrent Q-agents with a learned 1-bit channel on the switch riddle (the
paper's Fig. 4 top experiment), expressed as a `repro.core.system.System`
so it runs through the same three runners and fused evaluator as every
other system. Centralised training: the channel is the continuous DRU
(sigmoid + noise), so TD gradients flow *between agents* through the
message; decentralised execution thresholds the message to a hard bit
(which is exactly what the generic greedy evaluator exercises via
``training=False``).

Training is trajectory-based BPTT over the rollout accumulator: the
executor rolls eps-greedily (messages riding along in `Transition.extras`),
and once a `rollout_len` trajectory is complete the trainer re-runs the
recurrent nets over it differentiably (same actions; DIAL recomputes the
messages with gradients, RIAL teacher-forces the stored hard bits) and
minimises the TD error of the chosen-action Q's against target-network
targets.

Memory handling follows the shared memory-core protocol
(`repro.nn.recurrent`): the per-agent GRU is a `ScannedRNN`, the executor
carry is the typed `repro.core.types.Carry` (hidden + outgoing messages),
boundary resets inside the BPTT scan use `reset_carry` at stored FIRST
rows, and the window-start memory comes from `window_start_carry` — the
executor stores its incoming carry per step in
``Transition.extras["carry_in"]`` (exactly like rec-PPO), so every BPTT
window re-runs from the *stored* executor state, even when a non-default
``rollout_len`` opens windows mid-episode.  (At the default
episode-aligned ``rollout_len = env.horizon`` the stored window-start
carry is the zeros the runner reset it to, so seed milestones are
unchanged from the earlier zero start-state code path.)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.buffer import (
    rollout_add,
    rollout_init,
    rollout_ready,
    rollout_reset,
    rollout_take,
)
from repro.core.modules.communication import BroadcastedCommunication, dru
from repro.core.system import System
from repro.core.types import Carry, TrainState, Transition
from repro.envs.api import StepType
from repro.nn import MLP, LinearScannedRNN, ScannedRNN
from repro.nn.recurrent import make_core, reset_carry, window_start_carry


@dataclasses.dataclass(frozen=True)
class DialConfig:
    """DIAL/RIAL hyperparameters (channel, exploration, BPTT window)."""
    hidden_dim: int = 64
    channel_size: int = 1
    noise_std: float = 0.5
    learning_rate: float = 5e-4
    gamma: float = 1.0
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_updates: int = 300
    target_update_period: int = 20
    max_grad_norm: float = 10.0
    use_comm: bool = True  # False -> ablation: recurrent independent MADQN
    # memory core behind the agents: "gru" (ScannedRNN reference — every
    # seed milestone is pinned on it) or "linear" (fused associative-scan
    # LinearScannedRNN). With the channel on, message feedback makes the
    # trajectory inherently sequential, so only the act-time step changes;
    # the no-comm ablation additionally re-runs BPTT as one fused unroll.
    recurrent_core: str = "gru"
    # "dial": differentiable DRU channel (gradients flow between agents)
    # "rial": discrete message chosen eps-greedily from a message Q-head and
    #         trained by Q-learning (no cross-agent gradients) — the RIAL
    #         baseline from Foerster et al. 2016
    protocol: str = "dial"
    # BPTT window; None -> the env's horizon (one episode per env per update)
    rollout_len: Optional[int] = None
    distributed_axis: Optional[str] = None  # pmean grads over this mesh axis


class DialNets(NamedTuple):
    """The shared per-agent network stack (encoder -> memory core -> heads)."""

    encoder: MLP
    core: ScannedRNN | LinearScannedRNN
    q_head: MLP
    msg_head: MLP


def make_dial(env, cfg: DialConfig = DialConfig()) -> System:
    """Build the DIAL (or RIAL, via ``cfg.protocol``) communicating `System`."""
    spec = env.spec()
    ids = list(spec.agent_ids)
    n = len(ids)
    obs_dim = spec.observations[ids[0]].shape[0]
    num_actions = spec.actions[ids[0]].num_values
    comm = BroadcastedCommunication(cfg.channel_size, cfg.noise_std, shared=True)
    in_dim = obs_dim + (comm.incoming_size(n) if cfg.use_comm else 0)
    rollout_len = cfg.rollout_len or int(env.horizon)

    rial = cfg.protocol == "rial"
    msg_out = 2 * cfg.channel_size if rial else cfg.channel_size
    nets = DialNets(
        encoder=MLP((in_dim, cfg.hidden_dim), activate_final=True),
        core=make_core(cfg.recurrent_core, cfg.hidden_dim, cfg.hidden_dim),
        q_head=MLP((cfg.hidden_dim, cfg.hidden_dim, num_actions)),
        msg_head=MLP((cfg.hidden_dim, cfg.hidden_dim, msg_out)),
    )
    # The channel feeds each step's messages into the next step's inputs,
    # so with comm on the BPTT re-run is inherently sequential.  Without it
    # (the rec-madqn ablation) inputs are the stored observations alone,
    # and the fused core can unroll the whole window in one kernel call.
    fused_bptt = (not cfg.use_comm) and (not rial) and cfg.recurrent_core != "gru"
    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )

    def init_train(key):
        """Initialise the `TrainState` (params, targets, optimizer, steps)."""
        k1, k2, k3, k4 = jax.random.split(key, 4)
        params = {
            "encoder": nets.encoder.init(k1),
            "core": nets.core.init(k2),
            "q_head": nets.q_head.init(k3),
            "msg_head": nets.msg_head.init(k4),
        }
        return TrainState(params, params, opt.init(params), jnp.zeros((), jnp.int32))

    def agent_step(params, obs_a, msg_in, h):
        """One memory-core step for one agent (shared weights)."""
        x = jnp.concatenate([obs_a, msg_in], axis=-1) if cfg.use_comm else obs_a
        z = nets.encoder.apply(params["encoder"], x)
        h, y = nets.core.step(params["core"], h, z)
        q = nets.q_head.apply(params["q_head"], y)
        m = nets.msg_head.apply(params["msg_head"], y)
        return q, m, h

    def initial_carry(batch_shape):
        """The executor's initial memory for a ``batch_shape`` of envs."""
        return Carry(
            hidden={a: nets.core.initial_carry(batch_shape) for a in ids},
            message={
                a: jnp.zeros((*batch_shape, cfg.channel_size)) for a in ids
            },
        )

    def eps_at(steps):
        """Linearly-decayed exploration epsilon after ``steps`` updates."""
        frac = jnp.clip(steps / cfg.eps_decay_updates, 0.0, 1.0)
        return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)

    def _no_msg(obs_a):
        return jnp.zeros(obs_a.shape[:-1] + (0,))

    # ------------------------------------------------------------ executor

    def select_actions(train: TrainState, obs, state, carry, key, training=True):
        """Eps-greedy act step; messages ride the typed `Carry` and extras."""
        del state  # decentralised execution
        k_dru, k_act = jax.random.split(key)
        incoming = comm.route(carry.message) if cfg.use_comm else None
        eps = eps_at(train.steps) if training else 0.0
        actions, new_h, out_msgs, msg_bits = {}, {}, {}, {}
        for i, a in enumerate(ids):
            msg_in = incoming[a] if cfg.use_comm else _no_msg(obs[a])
            q, m, h = agent_step(train.params, obs[a], msg_in, carry.hidden[a])
            greedy = jnp.argmax(q, axis=-1)
            k_rand, k_explore = jax.random.split(jax.random.fold_in(k_act, i))
            rand = jax.random.randint(k_rand, greedy.shape, 0, num_actions)
            explore = jax.random.uniform(k_explore, greedy.shape) < eps
            actions[a] = jnp.where(explore, rand, greedy).astype(jnp.int32)
            if rial:
                # RIAL: the message bit is an eps-greedy action from the
                # message Q-head (hard bit in training and execution)
                qm = m.reshape(m.shape[:-1] + (cfg.channel_size, 2))
                bit_greedy = jnp.argmax(qm, axis=-1)
                km_rand, km_explore = jax.random.split(
                    jax.random.fold_in(k_dru, i)
                )
                bit_rand = jax.random.randint(km_rand, bit_greedy.shape, 0, 2)
                bit_explore = jax.random.uniform(km_explore, bit_greedy.shape) < eps
                bit = jnp.where(bit_explore, bit_rand, bit_greedy).astype(
                    jnp.int32
                )
                msg_bits[a] = bit
                out_msgs[a] = bit.astype(jnp.float32)
            else:
                # DIAL: DRU (continuous in training, hard bit execution)
                out_msgs[a] = dru(
                    m, jax.random.fold_in(k_dru, i), cfg.noise_std, training
                )
            new_h[a] = h
        # the incoming carry rides along so BPTT windows re-run from the
        # exact stored executor memory (window_start_carry's stored path)
        extras = {"msgs": out_msgs, "carry_in": carry}
        if rial:
            extras["msg_bits"] = msg_bits
        return actions, Carry(hidden=new_h, message=out_msgs), extras

    # ------------------------------------------------------------- trainer

    def q_trajectory(params, traj: Transition, key, training: bool):
        """Differentiable re-run over a stored (T, B) trajectory.

        DIAL: messages are recomputed with gradients (the channel is part of
        the computation graph). RIAL: stored hard bits are teacher-forced
        (no cross-agent gradients); returns message Q-values as well.
        Memory is reset at stored FIRST rows via the shared `reset_carry`
        rule, and the window opens from `window_start_carry`'s *stored*
        path — the executor records its incoming carry per step in
        ``extras["carry_in"]``, so mid-episode window starts replay the
        true executor memory (on-policy rollouts never span a parameter
        update, so the stored carry is exact).  Ends with one bootstrap
        step on the final next-observation.  Returns
        (qs, q_boot, msg_qs, msg_q_boot) — the msg outputs are {} for DIAL.

        When the channel is off and the memory core is linear (the
        ``fused_bptt`` condition above), there is no step-to-step message
        feedback, so the whole window's inputs are known up front and the
        re-run collapses to one fused ``core.unroll`` per agent (FIRST
        rows folded into the scan as resets) instead of a sequential
        per-step scan.
        """
        B = traj.discount.shape[1]
        carry0 = window_start_carry(traj.extras, initial_carry, (B,))

        if fused_bptt:
            first = traj.step_type == StepType.FIRST  # (T, B)
            qs, q_boot = {}, {}
            for a in ids:
                z = nets.encoder.apply(params["encoder"], traj.obs[a])
                h_fin, hs = nets.core.unroll(
                    params["core"], carry0.hidden[a], z, resets=first
                )
                qs[a] = nets.q_head.apply(params["q_head"], hs)
                # bootstrap step on the final next-obs (no reset row),
                # matching the sequential path's trailing `cell` call
                last_obs = traj.next_obs[a][-1]
                qb, _, _ = agent_step(params, last_obs, _no_msg(last_obs), h_fin)
                q_boot[a] = qb
            return qs, q_boot, {}, {}

        def cell(carry, key, obs_t, msgs_t):
            """One re-run step: per-agent Q/message/hidden from a row."""
            k_dru = key
            incoming = comm.route(carry.message) if cfg.use_comm else None
            qs, new_h, out_msgs, msg_qs = {}, {}, {}, {}
            for i, a in enumerate(ids):
                msg_in = incoming[a] if cfg.use_comm else _no_msg(obs_t[a])
                q, m, h = agent_step(params, obs_t[a], msg_in, carry.hidden[a])
                qs[a] = q
                new_h[a] = h
                if rial:
                    msg_qs[a] = m.reshape(m.shape[:-1] + (cfg.channel_size, 2))
                    out_msgs[a] = msgs_t[a]  # teacher-forced bits
                else:
                    out_msgs[a] = dru(
                        m, jax.random.fold_in(k_dru, i), cfg.noise_std, training
                    )
            return Carry(hidden=new_h, message=out_msgs), qs, msg_qs

        def step(c, data_t):
            """One BPTT row: reset memory at FIRST rows, then apply the cell."""
            carry, key = c
            key, k_dru = jax.random.split(key)
            # memory (hidden + stale messages) restarts where this row
            # starts a new episode, matching the executor's auto-reset carry
            first = data_t.step_type == StepType.FIRST
            carry = reset_carry(carry, first)
            carry, qs, msg_qs = cell(carry, k_dru, data_t.obs, data_t.extras["msgs"])
            return (carry, key), (qs, msg_qs)

        (carry, key), (qs, msg_qs) = jax.lax.scan(step, (carry0, key), traj)
        # bootstrap step on the final next-obs (gated by discount in the loss)
        last_obs = jax.tree_util.tree_map(lambda x: x[-1], traj.next_obs)
        last_msgs = {a: traj.extras["msgs"][a][-1] for a in ids}
        _, q_boot, msg_q_boot = cell(carry, key, last_obs, last_msgs)
        return qs, q_boot, msg_qs, msg_q_boot

    def loss_fn(params, target_params, traj: Transition, key):
        """Mean TD error of the re-run Q's (plus message TD for RIAL)."""
        k1, k2 = jax.random.split(key)
        qs, q_boot, msg_qs, msg_q_boot = q_trajectory(params, traj, k1, True)
        qs_t, q_boot_t, msg_qs_t, msg_q_boot_t = jax.tree_util.tree_map(
            jax.lax.stop_gradient, q_trajectory(target_params, traj, k2, True)
        )
        total, count = 0.0, 0.0
        d = traj.discount  # (T, B), 0 at terminal rows
        for a in ids:
            q = qs[a]  # (T, B, A)
            qa = jnp.take_along_axis(q, traj.actions[a][..., None], -1)[..., 0]
            q_next = jnp.concatenate([qs_t[a][1:], q_boot_t[a][None]], axis=0)
            target = traj.rewards[a] + cfg.gamma * d * jnp.max(q_next, axis=-1)
            td = qa - jax.lax.stop_gradient(target)
            total = total + jnp.sum(jnp.square(td))
            count = count + td.size
            if rial:
                # message-bit Q-learning (RIAL trains the channel by TD)
                qm = msg_qs[a]  # (T, B, C, 2)
                bits = traj.extras["msg_bits"][a][..., None]  # (T, B, C, 1)
                qmb = jnp.take_along_axis(qm, bits, -1)[..., 0]  # (T, B, C)
                qm_next = jnp.concatenate(
                    [msg_qs_t[a][1:], msg_q_boot_t[a][None]], axis=0
                )
                tgt = (
                    traj.rewards[a][..., None]
                    + cfg.gamma * d[..., None] * jnp.max(qm_next, axis=-1)
                )
                td_m = qmb - jax.lax.stop_gradient(tgt)
                total = total + jnp.sum(jnp.square(td_m))
                count = count + td_m.size
        return total / count

    def update(train: TrainState, buffer, key):
        """One BPTT update over the consumed rollout (+ periodic target sync)."""
        traj = rollout_take(buffer)
        loss, grads = jax.value_and_grad(loss_fn)(
            train.params, train.target_params, traj, key
        )
        if cfg.distributed_axis:
            grads = jax.lax.pmean(grads, cfg.distributed_axis)
        updates, opt_state = opt.update(grads, train.opt_state, train.params)
        params = optim.apply_updates(train.params, updates)
        steps = train.steps + 1
        target_params = jax.tree_util.tree_map(
            lambda t, o: jnp.where(steps % cfg.target_update_period == 0, o, t),
            train.target_params,
            params,
        )
        return (
            TrainState(params, target_params, opt_state, steps),
            rollout_reset(buffer),
            {"loss": loss, "eps": eps_at(steps)},
        )

    # ------------------------------------------------------------- dataset

    def example_transition():
        """A zero `Transition` fixing the buffer's shapes and dtypes."""
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        extras = {
            "msgs": {a: jnp.zeros((cfg.channel_size,)) for a in ids},
            "carry_in": initial_carry(()),
        }
        if rial:
            extras["msg_bits"] = {
                a: jnp.zeros((cfg.channel_size,), jnp.int32) for a in ids
            }
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((), jnp.int32) for a in ids},
            rewards={a: jnp.zeros(()) for a in ids},
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            extras=extras,
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        """A fresh experience buffer for ``num_envs`` parallel envs."""
        return rollout_init(example_transition(), rollout_len, num_envs)

    name = cfg.protocol if cfg.use_comm else "rec-madqn"
    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=rollout_add,
        can_sample=lambda buf: rollout_ready(buf, rollout_len),
        name=name,
    )
