"""MAPPO — PPO with centralised critics on the global state (CTDE)."""
from repro.systems.onpolicy import PPOConfig, make_mappo

__all__ = ["make_mappo", "PPOConfig"]
