"""V-trace off-policy corrected returns (IMPALA, Espeholt et al. 2018).

The async actor/learner runner (`repro.distributed.impala`) lets actors
collect trajectories under a *stale* parameter snapshot while the learner
has already moved on — so the on-policy PPO family's GAE, which assumes
behaviour == target policy, is biased whenever ``param_sync_every > 1``.
V-trace repairs this with truncated importance sampling: per-step ratios
``rho_t = min(clip_rho, pi(a_t|x_t) / mu(a_t|x_t))`` correct each TD
error toward the *current* policy's value, and trace coefficients
``c_t = lam * min(clip_c, rho_t)`` decay how far corrections propagate
backwards:

    vs_t - V(x_t) = delta_t + d_t * c_t * (vs_{t+1} - V(x_{t+1}))
    delta_t       = rho_t * (r_t + d_t * V(x_{t+1}) - V(x_t))

with ``d_t`` the discounted continuation (``gamma * discount_t``).  The
value targets are ``vs_t``; the policy-gradient advantages are
``rho_t * (r_t + d_t * vs_{t+1} - V(x_t))``.

On-policy (``rho = c = 1``) with ``lam = 1`` both reduce exactly to this
repo's GAE advantages and returns (`repro.systems.onpolicy._make_gae`) —
the equivalence is pinned by ``tests/test_async.py``, which anchors the
implementation without any reference code.  With ``lam < 1`` the trace
decay enters the recursion through ``c_t`` (the standard IMPALA
``lambda_`` knob), which differs from GAE's placement of ``lam`` by a
single-step bootstrap term, so exact equivalence is a ``lam = 1``
statement only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vtrace_advantages(
    curr_logp,
    behaviour_logp,
    values,
    last_value,
    rewards,
    discounts,
    clip_rho: float = 1.0,
    clip_c: float = 1.0,
    lam: float = 1.0,
):
    """V-trace policy-gradient advantages and value targets.

    All per-step inputs are time-major ``(T, B)`` arrays for one agent:
    ``curr_logp`` / ``behaviour_logp`` the log-probability of the taken
    action under the current (learner) and behaviour (actor snapshot)
    policies, ``values`` the *current* critic's V(x_t), ``last_value`` the
    ``(B,)`` bootstrap V(x_T), ``rewards`` the agent's rewards and
    ``discounts`` the discounted continuation ``gamma * discount_t``
    (zero at terminal rows, which gates bootstrapping exactly as in GAE).

    Returns ``(pg_advantages, vs)`` — feed the first (normalised) to the
    policy loss and the second to the value loss, in the positions GAE's
    ``(adv, ret)`` occupy.
    """
    rho = jnp.minimum(clip_rho, jnp.exp(curr_logp - behaviour_logp))
    c = lam * jnp.minimum(clip_c, jnp.exp(curr_logp - behaviour_logp))
    v_next = jnp.concatenate([values[1:], last_value[None]], axis=0)
    delta = rho * (rewards + discounts * v_next - values)

    def back(err_next, inp):
        delta_t, d_t, c_t = inp
        err_t = delta_t + d_t * c_t * err_next
        return err_t, err_t

    _, errors = jax.lax.scan(
        back, jnp.zeros_like(last_value), (delta, discounts, c), reverse=True
    )
    vs = values + errors
    vs_next = jnp.concatenate([vs[1:], last_value[None]], axis=0)
    pg_adv = rho * (rewards + discounts * vs_next - values)
    return pg_adv, vs
