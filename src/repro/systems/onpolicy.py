"""On-policy PPO family: IPPO (decentralised critics) / MAPPO (centralised).

The flagship systems of JAX-Mava, expressed as `repro.core.system.System`
instances so they run through the same three runners (python loop, Anakin,
shard_map) and the fused evaluator as every other system. The dataset half
is the rollout accumulator (`repro.core.buffer.RolloutState`): the executor
streams transitions — with behaviour log-probs and values riding along in
`Transition.extras` — into a time-major `rollout_len` buffer, and the
`rollout_len`-gated `update` consumes the whole trajectory (per-agent GAE,
PPO epochs with clipped objective + entropy bonus) and resets it.

MAPPO's critic conditions on the global environment state
(CentralisedQValueCritic architecture); IPPO's on each agent's observation.
Advantages are computed from *per-agent* rewards, so general-sum scenarios
(e.g. batched matrix games with distinct payoffs) are handled correctly.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.buffer import (
    rollout_add,
    rollout_init,
    rollout_ready,
    rollout_reset,
    rollout_take,
)
from repro.core.system import System
from repro.core.types import TrainState, Transition
from repro.envs.api import EnvSpec
from repro.nn import MLP


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    hidden_sizes: Sequence[int] = (64, 64)
    learning_rate: float = 3e-4
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    epochs: int = 4
    num_minibatches: int = 4
    max_grad_norm: float = 0.5
    rollout_len: int = 128
    shared_weights: bool = True
    distributed_axis: str | None = None


def make_ppo_networks(env, cfg: PPOConfig, centralised: bool):
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    num_actions = {a: spec.actions[a].num_values for a in ids}
    obs_dims = {a: spec.observations[a].shape[0] for a in ids}
    state_dim = spec.state.shape[0]

    homogeneous = len(set((obs_dims[a], num_actions[a]) for a in ids)) == 1
    share = cfg.shared_weights and homogeneous

    actors = {a: MLP((obs_dims[a], *cfg.hidden_sizes, num_actions[a])) for a in ids}
    critic_in = {a: (state_dim if centralised else obs_dims[a]) for a in ids}
    critics = {a: MLP((critic_in[a], *cfg.hidden_sizes, 1)) for a in ids}

    def init(key):
        ka, kc = jax.random.split(key)
        if share:
            return {
                "actor": {"shared": actors[ids[0]].init(ka)},
                "critic": {"shared": critics[ids[0]].init(kc)},
            }
        kas = jax.random.split(ka, len(ids))
        kcs = jax.random.split(kc, len(ids))
        return {
            "actor": {a: actors[a].init(k) for a, k in zip(ids, kas)},
            "critic": {a: critics[a].init(k) for a, k in zip(ids, kcs)},
        }

    def logits(params, agent, obs):
        p = params["actor"]["shared"] if share else params["actor"][agent]
        return actors[agent].apply(p, obs)

    def value(params, agent, critic_obs):
        p = params["critic"]["shared"] if share else params["critic"][agent]
        return critics[agent].apply(p, critic_obs)[..., 0]

    return ids, num_actions, init, logits, value


def make_ppo_system(env, cfg: PPOConfig, centralised: bool, name: str) -> System:
    spec: EnvSpec = env.spec()
    ids, num_actions, init_params, logits_fn, value_fn = make_ppo_networks(
        env, cfg, centralised
    )
    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )

    def critic_obs(obs, state, agent):
        return state if centralised else obs[agent]

    def init_train(key):
        params = init_params(key)
        return TrainState(params, params, opt.init(params), jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------ executor

    def select_actions(train: TrainState, obs, state, carry, key, training=True):
        params = train.params
        if not training:
            # greedy execution (fused evaluator): no log-probs/values needed
            actions = {
                a: jnp.argmax(logits_fn(params, a, obs[a]), axis=-1).astype(
                    jnp.int32
                )
                for a in ids
            }
            return actions, carry, {}
        actions, logps, values = {}, {}, {}
        for i, a in enumerate(ids):
            lg = logits_fn(params, a, obs[a])
            act_ = jax.random.categorical(jax.random.fold_in(key, i), lg)
            lp = jax.nn.log_softmax(lg)
            logps[a] = jnp.take_along_axis(lp, act_[..., None], axis=-1)[..., 0]
            actions[a] = act_.astype(jnp.int32)
            values[a] = value_fn(params, a, critic_obs(obs, state, a))
        return actions, carry, {"logp": logps, "value": values}

    def initial_carry(batch_shape):
        del batch_shape
        return ()

    # ------------------------------------------------------------- trainer

    def gae(traj: Transition, last_values):
        """Per-agent GAE over the time-major trajectory (T, B)."""
        adv, ret = {}, {}
        values = traj.extras["value"]
        disc = traj.discount * cfg.gamma
        for a in ids:
            v = values[a]          # (T, B) behaviour values
            r = traj.rewards[a]    # (T, B) this agent's reward

            def back(carry, inp):
                gae_t, v_next = carry
                v_t, r_t, d_t = inp
                delta = r_t + d_t * v_next - v_t
                gae_t = delta + d_t * cfg.gae_lambda * gae_t
                return (gae_t, v_t), gae_t

            (_, _), advs = jax.lax.scan(
                back,
                (jnp.zeros_like(last_values[a]), last_values[a]),
                (v, r, disc),
                reverse=True,
            )
            adv[a] = advs
            ret[a] = advs + v
        return adv, ret

    def loss_fn(params, minibatch):
        total = 0.0
        metrics = {}
        for a in ids:
            lg = logits_fn(params, a, minibatch["obs"][a])
            lp_all = jax.nn.log_softmax(lg)
            lp = jnp.take_along_axis(
                lp_all, minibatch["actions"][a][..., None], axis=-1
            )[..., 0]
            ratio = jnp.exp(lp - minibatch["logp"][a])
            adv = minibatch["advantage"][a]
            adv = (adv - adv.mean()) / (adv.std() + 1e-8)
            pg = -jnp.minimum(
                ratio * adv,
                jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv,
            )
            v = value_fn(
                params, a, critic_obs(minibatch["obs"], minibatch["state"], a)
            )
            v_loss = jnp.square(v - minibatch["returns"][a])
            ent = -jnp.sum(jnp.exp(lp_all) * lp_all, axis=-1)
            total = total + jnp.mean(
                pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
            )
        metrics["loss"] = total
        return total, metrics

    def update(train: TrainState, buffer, key):
        traj: Transition = rollout_take(buffer)  # leaves (T, B, ...)
        # Bootstrap from the final next-observation. Params are unchanged
        # since the rollout began (on-policy: no update fired mid-rollout),
        # so these are behaviour values, exactly as if recorded at act time.
        last_obs = jax.tree_util.tree_map(lambda x: x[-1], traj.next_obs)
        last_state = traj.next_state[-1]
        last_values = {
            a: value_fn(train.params, a, critic_obs(last_obs, last_state, a))
            for a in ids
        }
        adv, ret = gae(traj, last_values)
        T, B = traj.discount.shape
        data = dict(
            obs=traj.obs,
            state=traj.state,
            actions=traj.actions,
            logp=traj.extras["logp"],
            advantage=adv,
            returns=ret,
        )
        flat = jax.tree_util.tree_map(
            lambda x: x.reshape((T * B,) + x.shape[2:]), data
        )

        def epoch(carry, _):
            params, opt_state, key = carry
            key, kp = jax.random.split(key)
            perm = jax.random.permutation(kp, T * B)
            shuffled = jax.tree_util.tree_map(lambda x: x[perm], flat)
            mb_size = (T * B) // cfg.num_minibatches
            mbs = jax.tree_util.tree_map(
                lambda x: x[: mb_size * cfg.num_minibatches].reshape(
                    (cfg.num_minibatches, mb_size) + x.shape[1:]
                ),
                shuffled,
            )

            def mb_step(carry, mb):
                params, opt_state = carry
                (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                if cfg.distributed_axis:
                    grads = jax.lax.pmean(grads, cfg.distributed_axis)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = optim.apply_updates(params, updates)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                mb_step, (params, opt_state), mbs
            )
            return (params, opt_state, key), jnp.mean(losses)

        (params, opt_state, _), losses = jax.lax.scan(
            epoch, (train.params, train.opt_state, key), None, length=cfg.epochs
        )
        new_train = TrainState(params, params, opt_state, train.steps + 1)
        return new_train, rollout_reset(buffer), {"loss": jnp.mean(losses)}

    # ------------------------------------------------------------- dataset

    def example_transition():
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        scalars = {a: jnp.zeros(()) for a in ids}
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((), jnp.int32) for a in ids},
            rewards=dict(scalars),
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            extras={"logp": dict(scalars), "value": dict(scalars)},
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        return rollout_init(example_transition(), cfg.rollout_len, num_envs)

    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=rollout_add,
        can_sample=lambda buf: rollout_ready(buf, cfg.rollout_len),
        name=name,
    )


def make_ippo(env, cfg: PPOConfig = PPOConfig()) -> System:
    return make_ppo_system(env, cfg, centralised=False, name="ippo")


def make_mappo(env, cfg: PPOConfig = PPOConfig()) -> System:
    return make_ppo_system(env, cfg, centralised=True, name="mappo")
