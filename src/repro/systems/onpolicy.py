"""On-policy PPO family: IPPO (decentralised critics) / MAPPO (centralised).

The flagship systems of JAX-Mava. Fully-fused Anakin training: each update
collects a `rollout_len` trajectory from `num_envs` vectorised environments
inside the same jit as the PPO epochs (GAE, clipped objective, entropy
bonus). MAPPO's critic conditions on the global environment state
(CentralisedQValueCritic architecture); IPPO's on each agent's observation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.types import TrainState
from repro.envs.api import EnvSpec, StepType
from repro.nn import MLP


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    hidden_sizes: Sequence[int] = (64, 64)
    learning_rate: float = 3e-4
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    epochs: int = 4
    num_minibatches: int = 4
    max_grad_norm: float = 0.5
    rollout_len: int = 128
    shared_weights: bool = True
    distributed_axis: str | None = None


class PPOBatch(NamedTuple):
    obs: dict
    state: jnp.ndarray
    actions: dict
    logp: dict
    value: dict
    reward: jnp.ndarray      # shared scalar (mean over agents)
    discount: jnp.ndarray
    advantage: dict
    returns: dict


def make_ppo_networks(env, cfg: PPOConfig, centralised: bool):
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    num_actions = {a: spec.actions[a].num_values for a in ids}
    obs_dims = {a: spec.observations[a].shape[0] for a in ids}
    state_dim = spec.state.shape[0]

    homogeneous = len(set((obs_dims[a], num_actions[a]) for a in ids)) == 1
    share = cfg.shared_weights and homogeneous

    actors = {a: MLP((obs_dims[a], *cfg.hidden_sizes, num_actions[a])) for a in ids}
    critic_in = {a: (state_dim if centralised else obs_dims[a]) for a in ids}
    critics = {a: MLP((critic_in[a], *cfg.hidden_sizes, 1)) for a in ids}

    def init(key):
        ka, kc = jax.random.split(key)
        if share:
            return {
                "actor": {"shared": actors[ids[0]].init(ka)},
                "critic": {"shared": critics[ids[0]].init(kc)},
            }
        kas = jax.random.split(ka, len(ids))
        kcs = jax.random.split(kc, len(ids))
        return {
            "actor": {a: actors[a].init(k) for a, k in zip(ids, kas)},
            "critic": {a: critics[a].init(k) for a, k in zip(ids, kcs)},
        }

    def logits(params, agent, obs):
        p = params["actor"]["shared"] if share else params["actor"][agent]
        return actors[agent].apply(p, obs)

    def value(params, agent, critic_obs):
        p = params["critic"]["shared"] if share else params["critic"][agent]
        return critics[agent].apply(p, critic_obs)[..., 0]

    return ids, num_actions, init, logits, value


@dataclasses.dataclass(frozen=True)
class PPOSystem:
    env: object
    spec: EnvSpec
    cfg: PPOConfig
    centralised: bool
    name: str

    def build(self):
        env, cfg = self.env, self.cfg
        ids, num_actions, init_params, logits_fn, value_fn = make_ppo_networks(
            env, cfg, self.centralised
        )
        opt = optim.chain(
            optim.clip_by_global_norm(cfg.max_grad_norm),
            optim.adamw(cfg.learning_rate),
        )
        centralised = self.centralised

        def critic_obs(obs, state, agent):
            return state if centralised else obs[agent]

        def init_train(key):
            params = init_params(key)
            return TrainState(params, params, opt.init(params), jnp.zeros((), jnp.int32))

        def act(params, obs, state, key):
            actions, logps, values = {}, {}, {}
            for i, a in enumerate(ids):
                lg = logits_fn(params, a, obs[a])
                k = jax.random.fold_in(key, i)
                act_ = jax.random.categorical(k, lg)
                lp = jax.nn.log_softmax(lg)
                logps[a] = jnp.take_along_axis(lp, act_[..., None], axis=-1)[..., 0]
                actions[a] = act_.astype(jnp.int32)
                values[a] = value_fn(params, a, critic_obs(obs, state, a))
            return actions, logps, values

        def rollout(params, env_state, ts, key):
            """Collect cfg.rollout_len steps from vmapped envs."""

            def step(carry, _):
                env_state, ts, key = carry
                key, k_act, k_reset = jax.random.split(key, 3)
                obs = ts.observation
                gs = jax.vmap(env.global_state)(env_state)
                actions, logps, values = act(params, obs, gs, k_act)
                new_env_state, new_ts = jax.vmap(env.step)(env_state, actions)
                reward = jnp.mean(jnp.stack(list(new_ts.reward.values())), axis=0)
                done = new_ts.step_type == StepType.LAST
                n = done.shape[0]
                r_state, r_ts = jax.vmap(env.reset)(jax.random.split(k_reset, n))

                def sel(new, old):
                    d = done.reshape(done.shape + (1,) * (new.ndim - 1))
                    return jnp.where(d, new, old)

                env_state2 = jax.tree_util.tree_map(sel, r_state, new_env_state)
                ts2 = jax.tree_util.tree_map(sel, r_ts, new_ts)
                data = dict(
                    obs=obs,
                    state=gs,
                    actions=actions,
                    logp=logps,
                    value=values,
                    reward=reward,
                    discount=new_ts.discount,
                )
                return (env_state2, ts2, key), data

            (env_state, ts, key), traj = jax.lax.scan(
                step, (env_state, ts, key), None, length=cfg.rollout_len
            )
            return env_state, ts, traj

        def gae(traj, last_values):
            adv, ret = {}, {}
            for a in ids:
                v = traj["value"][a]  # (T, B)
                r = traj["reward"]
                disc = traj["discount"] * cfg.gamma

                def back(carry, inp):
                    gae_t, v_next = carry
                    v_t, r_t, d_t = inp
                    delta = r_t + d_t * v_next - v_t
                    gae_t = delta + d_t * cfg.gae_lambda * gae_t
                    return (gae_t, v_t), gae_t

                (_, _), advs = jax.lax.scan(
                    back,
                    (jnp.zeros_like(last_values[a]), last_values[a]),
                    (v, r, disc),
                    reverse=True,
                )
                adv[a] = advs
                ret[a] = advs + v
            return adv, ret

        def loss_fn(params, minibatch):
            total = 0.0
            metrics = {}
            for a in ids:
                lg = logits_fn(params, a, minibatch["obs"][a])
                lp_all = jax.nn.log_softmax(lg)
                lp = jnp.take_along_axis(
                    lp_all, minibatch["actions"][a][..., None], axis=-1
                )[..., 0]
                ratio = jnp.exp(lp - minibatch["logp"][a])
                adv = minibatch["advantage"][a]
                adv = (adv - adv.mean()) / (adv.std() + 1e-8)
                pg = -jnp.minimum(
                    ratio * adv,
                    jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv,
                )
                v = value_fn(
                    params, a, critic_obs(minibatch["obs"], minibatch["state"], a)
                )
                v_loss = jnp.square(v - minibatch["returns"][a])
                ent = -jnp.sum(jnp.exp(lp_all) * lp_all, axis=-1)
                total = total + jnp.mean(
                    pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
                )
            metrics["loss"] = total
            return total, metrics

        def update(train: TrainState, traj, last_values, key):
            adv, ret = gae(traj, last_values)
            T = cfg.rollout_len
            B = traj["reward"].shape[1]
            data = dict(traj, advantage=adv, returns=ret)
            flat = jax.tree_util.tree_map(
                lambda x: x.reshape((T * B,) + x.shape[2:]), data
            )

            def epoch(carry, _):
                params, opt_state, key = carry
                key, kp = jax.random.split(key)
                perm = jax.random.permutation(kp, T * B)
                shuffled = jax.tree_util.tree_map(lambda x: x[perm], flat)
                mb_size = (T * B) // cfg.num_minibatches
                mbs = jax.tree_util.tree_map(
                    lambda x: x[: mb_size * cfg.num_minibatches].reshape(
                        (cfg.num_minibatches, mb_size) + x.shape[1:]
                    ),
                    shuffled,
                )

                def mb_step(carry, mb):
                    params, opt_state = carry
                    (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb
                    )
                    if cfg.distributed_axis:
                        grads = jax.lax.pmean(grads, cfg.distributed_axis)
                    updates, opt_state = opt.update(grads, opt_state, params)
                    params = optim.apply_updates(params, updates)
                    return (params, opt_state), loss

                (params, opt_state), losses = jax.lax.scan(
                    mb_step, (params, opt_state), mbs
                )
                return (params, opt_state, key), jnp.mean(losses)

            (params, opt_state, _), losses = jax.lax.scan(
                epoch, (train.params, train.opt_state, key), None, length=cfg.epochs
            )
            return (
                TrainState(params, params, opt_state, train.steps + 1),
                {"loss": jnp.mean(losses)},
            )

        def train_fn(key, num_updates: int, num_envs: int):
            k_init, k_env, k_run = jax.random.split(key, 3)
            train = init_train(k_init)
            env_state, ts = jax.vmap(env.reset)(jax.random.split(k_env, num_envs))

            @jax.jit
            def run(train, env_state, ts, key):
                def one_update(carry, _):
                    train, env_state, ts, key = carry
                    key, k_roll, k_upd, k_last = jax.random.split(key, 4)
                    env_state, ts, traj = rollout(train.params, env_state, ts, k_roll)
                    gs = jax.vmap(env.global_state)(env_state)
                    _, _, last_values = act(train.params, ts.observation, gs, k_last)
                    train, metrics = update(train, traj, last_values, k_upd)
                    metrics["reward"] = jnp.mean(traj["reward"])
                    return (train, env_state, ts, key), metrics

                return jax.lax.scan(
                    one_update, (train, env_state, ts, key), None, length=num_updates
                )

            (train, *_), metrics = run(train, env_state, ts, k_run)
            return train, metrics

        return dict(
            init_train=init_train,
            act=act,
            rollout=rollout,
            update=update,
            train=train_fn,
            ids=ids,
            name=self.name,
        )


def make_ippo(env, cfg: PPOConfig = PPOConfig()):
    return PPOSystem(env, env.spec(), cfg, centralised=False, name="ippo").build()


def make_mappo(env, cfg: PPOConfig = PPOConfig()):
    return PPOSystem(env, env.spec(), cfg, centralised=True, name="mappo").build()
