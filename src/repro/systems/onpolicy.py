"""On-policy PPO family: IPPO / MAPPO, feed-forward and recurrent.

The flagship systems of JAX-Mava, expressed as `repro.core.system.System`
instances so they run through the same three runners (python loop, Anakin,
shard_map) and the fused evaluator as every other system. The dataset half
is the rollout accumulator (`repro.core.buffer.RolloutState`): the executor
streams transitions — with behaviour log-probs and values riding along in
`Transition.extras` — into a time-major `rollout_len` buffer, and the
`rollout_len`-gated `update` consumes the whole trajectory (per-agent GAE,
PPO epochs with clipped objective + entropy bonus) and resets it.

Four variants from two axes:

* critic input — IPPO conditions each agent's critic on its own
  observation; MAPPO's centralised critic conditions on the global
  environment state (CTDE);
* memory — the feed-forward variants (``ippo`` / ``mappo``) use plain MLP
  actors; the recurrent variants (``rec_ippo`` / ``rec_mappo``) put a
  memory core between an MLP encoder and each head (a `repro.nn.ScannedRNN`
  GRU by default, or the fused-associative-scan `LinearScannedRNN` via
  ``PPOConfig.recurrent_core="linear"``), threading a typed `Carry`
  through the runners.  The paper's headline
  systems are the recurrent ones: on partially observable tasks
  (switch_game, speaker_listener, rware) a feed-forward policy is the
  wrong model class.

The recurrent trainer follows the shared memory-core protocol
(`repro.nn.recurrent`): the executor stores its incoming carry per step in
``Transition.extras["carry_in"]``, the update re-runs actor and critic
cores over the stored window from the *exact* stored start carry
(`window_start_carry` — on-policy windows never span a parameter update),
resets memory at stored FIRST rows inside the BPTT scan, and minibatches
over the env axis so sequences stay intact (the JaxMARL recurrent-PPO
idiom), instead of the feed-forward path's time-flattened shuffling.

Advantages are computed from *per-agent* rewards, so general-sum scenarios
(e.g. batched matrix games with distinct payoffs) are handled correctly.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.buffer import (
    rollout_add,
    rollout_init,
    rollout_ready,
    rollout_reset,
    rollout_take,
)
from repro.core.system import System
from repro.core.types import Carry, TrainState, Transition
from repro.envs.api import EnvSpec, StepType
from repro.nn import MLP
from repro.nn.recurrent import make_core, window_start_carry
from repro.systems.vtrace import vtrace_advantages


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    """Hyperparameters shared by all four PPO variants.

    ``hidden_sizes`` shapes the MLP trunk; the recurrent variants reuse it
    as the encoder widths and put a GRU core of ``hidden_sizes[-1]`` units
    between encoder and head.  ``num_minibatches`` divides the flattened
    ``rollout_len * num_envs`` rows for the feed-forward variants and the
    ``num_envs`` sequence axis for the recurrent ones (clamped to the
    number of envs, so the single-env python loop still trains).

    ``recurrent_core`` selects the memory core behind the recurrent
    variants (ignored by the feed-forward ones): ``"gru"`` is the
    `ScannedRNN` reference path every seed milestone is pinned on;
    ``"linear"`` swaps in the gated-linear `LinearScannedRNN`, whose BPTT
    unrolls run as one fused associative scan
    (`repro.kernels.recurrent_scan` — the throughput path, see
    docs/KERNELS.md).

    ``use_vtrace`` swaps GAE for V-trace off-policy corrected advantages
    (`repro.systems.vtrace`), re-evaluating values and log-probs under the
    *current* params and importance-weighting against the stored behaviour
    log-probs — required for correctness when trajectories are collected
    by stale-snapshot actors (the async runner with
    ``param_sync_every > 1``, see docs/DISTRIBUTED.md); a no-op
    generalisation of GAE when behaviour == current (exact at
    ``gae_lambda = 1``).  ``vtrace_clip_rho`` / ``vtrace_clip_c`` are the
    IMPALA truncation levels for the importance ratios and the trace
    coefficients.
    """

    hidden_sizes: Sequence[int] = (64, 64)
    learning_rate: float = 3e-4
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    epochs: int = 4
    num_minibatches: int = 4
    max_grad_norm: float = 0.5
    rollout_len: int = 128
    shared_weights: bool = True
    recurrent_core: str = "gru"
    distributed_axis: str | None = None
    use_vtrace: bool = False
    vtrace_clip_rho: float = 1.0
    vtrace_clip_c: float = 1.0


def _make_gae(cfg: PPOConfig, ids):
    """Per-agent GAE over a time-major (T, B) trajectory (shared by all variants)."""

    def gae(traj: Transition, last_values):
        """Per-agent advantages and returns for one stored trajectory."""
        adv, ret = {}, {}
        values = traj.extras["value"]
        disc = traj.discount * cfg.gamma
        for a in ids:
            v = values[a]          # (T, B) behaviour values
            r = traj.rewards[a]    # (T, B) this agent's reward

            def back(carry, inp):
                gae_t, v_next = carry
                v_t, r_t, d_t = inp
                delta = r_t + d_t * v_next - v_t
                gae_t = delta + d_t * cfg.gae_lambda * gae_t
                return (gae_t, v_t), gae_t

            (_, _), advs = jax.lax.scan(
                back,
                (jnp.zeros_like(last_values[a]), last_values[a]),
                (v, r, disc),
                reverse=True,
            )
            adv[a] = advs
            ret[a] = advs + v
        return adv, ret

    return gae


def _ppo_surrogate(cfg: PPOConfig, lp, lp_all, logp_old, adv, v, returns):
    """The clipped PPO objective for one agent's batch of rows (any shape)."""
    ratio = jnp.exp(lp - logp_old)
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg = -jnp.minimum(
        ratio * adv,
        jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv,
    )
    v_loss = jnp.square(v - returns)
    ent = -jnp.sum(jnp.exp(lp_all) * lp_all, axis=-1)
    return jnp.mean(pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent)


# ------------------------------------------------------------- feed-forward


def make_ppo_networks(env, cfg: PPOConfig, centralised: bool):
    """Build the feed-forward per-agent actor/critic MLPs (shared if homogeneous)."""
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    num_actions = {a: spec.actions[a].num_values for a in ids}
    obs_dims = {a: spec.observations[a].shape[0] for a in ids}
    state_dim = spec.state.shape[0]

    homogeneous = len(set((obs_dims[a], num_actions[a]) for a in ids)) == 1
    share = cfg.shared_weights and homogeneous

    actors = {a: MLP((obs_dims[a], *cfg.hidden_sizes, num_actions[a])) for a in ids}
    critic_in = {a: (state_dim if centralised else obs_dims[a]) for a in ids}
    critics = {a: MLP((critic_in[a], *cfg.hidden_sizes, 1)) for a in ids}

    def init(key):
        """Initialise actor/critic params (shared across agents if homogeneous)."""
        ka, kc = jax.random.split(key)
        if share:
            return {
                "actor": {"shared": actors[ids[0]].init(ka)},
                "critic": {"shared": critics[ids[0]].init(kc)},
            }
        kas = jax.random.split(ka, len(ids))
        kcs = jax.random.split(kc, len(ids))
        return {
            "actor": {a: actors[a].init(k) for a, k in zip(ids, kas)},
            "critic": {a: critics[a].init(k) for a, k in zip(ids, kcs)},
        }

    def logits(params, agent, obs):
        """Actor logits for one agent's observation batch."""
        p = params["actor"]["shared"] if share else params["actor"][agent]
        return actors[agent].apply(p, obs)

    def value(params, agent, critic_obs):
        """Critic value for one agent's (obs or state) batch."""
        p = params["critic"]["shared"] if share else params["critic"][agent]
        return critics[agent].apply(p, critic_obs)[..., 0]

    return ids, num_actions, init, logits, value


def make_ppo_system(env, cfg: PPOConfig, centralised: bool, name: str) -> System:
    """Build a feed-forward PPO `System` (IPPO or MAPPO by critic input)."""
    spec: EnvSpec = env.spec()
    ids, num_actions, init_params, logits_fn, value_fn = make_ppo_networks(
        env, cfg, centralised
    )
    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )

    def critic_obs(obs, state, agent):
        """The critic input: global state (MAPPO) or own obs (IPPO)."""
        return state if centralised else obs[agent]

    def init_train(key):
        """Initialise the `TrainState` (params, targets, optimizer, steps)."""
        params = init_params(key)
        return TrainState(params, params, opt.init(params), jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------ executor

    def select_actions(train: TrainState, obs, state, carry, key, training=True):
        """Sample actions; log-probs/values ride along in extras."""
        params = train.params
        if not training:
            # greedy execution (fused evaluator): no log-probs/values needed
            actions = {
                a: jnp.argmax(logits_fn(params, a, obs[a]), axis=-1).astype(
                    jnp.int32
                )
                for a in ids
            }
            return actions, carry, {}
        actions, logps, values = {}, {}, {}
        for i, a in enumerate(ids):
            lg = logits_fn(params, a, obs[a])
            act_ = jax.random.categorical(jax.random.fold_in(key, i), lg)
            lp = jax.nn.log_softmax(lg)
            logps[a] = jnp.take_along_axis(lp, act_[..., None], axis=-1)[..., 0]
            actions[a] = act_.astype(jnp.int32)
            values[a] = value_fn(params, a, critic_obs(obs, state, a))
        return actions, carry, {"logp": logps, "value": values}

    def initial_carry(batch_shape):
        """The executor's initial memory for a ``batch_shape`` of envs."""
        del batch_shape
        return ()

    # ------------------------------------------------------------- trainer

    gae = _make_gae(cfg, ids)

    def loss_fn(params, minibatch):
        """Summed per-agent clipped PPO surrogate over one minibatch."""
        total = 0.0
        metrics = {}
        for a in ids:
            lg = logits_fn(params, a, minibatch["obs"][a])
            lp_all = jax.nn.log_softmax(lg)
            lp = jnp.take_along_axis(
                lp_all, minibatch["actions"][a][..., None], axis=-1
            )[..., 0]
            v = value_fn(
                params, a, critic_obs(minibatch["obs"], minibatch["state"], a)
            )
            total = total + _ppo_surrogate(
                cfg, lp, lp_all, minibatch["logp"][a],
                minibatch["advantage"][a], v, minibatch["returns"][a],
            )
        metrics["loss"] = total
        return total, metrics

    def update(train: TrainState, buffer, key):
        """Consume the rollout: GAE or V-trace, then epochs of minibatches."""
        traj: Transition = rollout_take(buffer)  # leaves (T, B, ...)
        # Bootstrap from the final next-observation with the learner's
        # current params.  Under the synchronous runners these equal the
        # behaviour params (no update fired mid-rollout), so GAE sees
        # behaviour values exactly as if recorded at act time; under the
        # async runner with staleness they differ, and the V-trace branch
        # re-evaluates the whole trajectory under current params and
        # importance-corrects against the stored behaviour log-probs.
        last_obs = jax.tree_util.tree_map(lambda x: x[-1], traj.next_obs)
        last_state = traj.next_state[-1]
        last_values = {
            a: value_fn(train.params, a, critic_obs(last_obs, last_state, a))
            for a in ids
        }
        if cfg.use_vtrace:
            adv, ret = {}, {}
            disc = traj.discount * cfg.gamma
            for a in ids:
                lp_all = jax.nn.log_softmax(
                    logits_fn(train.params, a, traj.obs[a])
                )
                curr_lp = jnp.take_along_axis(
                    lp_all, traj.actions[a][..., None], axis=-1
                )[..., 0]
                curr_v = value_fn(
                    train.params, a, critic_obs(traj.obs, traj.state, a)
                )
                adv[a], ret[a] = vtrace_advantages(
                    curr_lp, traj.extras["logp"][a], curr_v, last_values[a],
                    traj.rewards[a], disc,
                    clip_rho=cfg.vtrace_clip_rho, clip_c=cfg.vtrace_clip_c,
                    lam=cfg.gae_lambda,
                )
        else:
            adv, ret = gae(traj, last_values)
        T, B = traj.discount.shape
        data = dict(
            obs=traj.obs,
            state=traj.state,
            actions=traj.actions,
            logp=traj.extras["logp"],
            advantage=adv,
            returns=ret,
        )
        flat = jax.tree_util.tree_map(
            lambda x: x.reshape((T * B,) + x.shape[2:]), data
        )

        def epoch(carry, _):
            """One PPO epoch: shuffle, split into minibatches, scan `mb_step`."""
            params, opt_state, key = carry
            key, kp = jax.random.split(key)
            perm = jax.random.permutation(kp, T * B)
            shuffled = jax.tree_util.tree_map(lambda x: x[perm], flat)
            mb_size = (T * B) // cfg.num_minibatches
            mbs = jax.tree_util.tree_map(
                lambda x: x[: mb_size * cfg.num_minibatches].reshape(
                    (cfg.num_minibatches, mb_size) + x.shape[1:]
                ),
                shuffled,
            )

            def mb_step(carry, mb):
                """One minibatch gradient step (optionally pmean over the mesh)."""
                params, opt_state = carry
                (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                if cfg.distributed_axis:
                    grads = jax.lax.pmean(grads, cfg.distributed_axis)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = optim.apply_updates(params, updates)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                mb_step, (params, opt_state), mbs
            )
            return (params, opt_state, key), jnp.mean(losses)

        (params, opt_state, _), losses = jax.lax.scan(
            epoch, (train.params, train.opt_state, key), None, length=cfg.epochs
        )
        new_train = TrainState(params, params, opt_state, train.steps + 1)
        return new_train, rollout_reset(buffer), {"loss": jnp.mean(losses)}

    # ------------------------------------------------------------- dataset

    def example_transition():
        """A zero `Transition` fixing the buffer's shapes and dtypes."""
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        scalars = {a: jnp.zeros(()) for a in ids}
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((), jnp.int32) for a in ids},
            rewards=dict(scalars),
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            extras={"logp": dict(scalars), "value": dict(scalars)},
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        """A fresh experience buffer for ``num_envs`` parallel envs."""
        return rollout_init(example_transition(), cfg.rollout_len, num_envs)

    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=rollout_add,
        can_sample=lambda buf: rollout_ready(buf, cfg.rollout_len),
        name=name,
    )


# --------------------------------------------------------------- recurrent


def make_recurrent_ppo_networks(env, cfg: PPOConfig, centralised: bool):
    """Build per-agent recurrent actor/critic stacks (encoder -> core -> head).

    Each network is an MLP encoder over ``cfg.hidden_sizes`` (final layer
    activated), a memory core of ``cfg.hidden_sizes[-1]`` units selected
    by ``cfg.recurrent_core`` (`ScannedRNN` GRU reference or the fused
    `LinearScannedRNN`), and a linear head.  Weights are shared across agents when the env is
    homogeneous and ``cfg.shared_weights`` is set (hidden *state* is always
    per-agent).  Returns ``(ids, num_actions, init, actor, critic)`` where
    ``actor`` / ``critic`` each expose ``step`` (one env step) and
    ``unroll`` (BPTT over a stored window with FIRST-row resets).
    """
    spec: EnvSpec = env.spec()
    ids = list(spec.agent_ids)
    num_actions = {a: spec.actions[a].num_values for a in ids}
    obs_dims = {a: spec.observations[a].shape[0] for a in ids}
    state_dim = spec.state.shape[0]
    hidden = cfg.hidden_sizes[-1]

    homogeneous = len(set((obs_dims[a], num_actions[a]) for a in ids)) == 1
    share = cfg.shared_weights and homogeneous
    critic_in = {a: (state_dim if centralised else obs_dims[a]) for a in ids}

    def stack(in_dim, out_dim):
        """One encoder -> memory core -> linear head network stack."""
        return {
            "encoder": MLP((in_dim, *cfg.hidden_sizes), activate_final=True),
            "core": make_core(cfg.recurrent_core, hidden, hidden),
            "head": MLP((hidden, out_dim)),
        }

    actors = {a: stack(obs_dims[a], num_actions[a]) for a in ids}
    critics = {a: stack(critic_in[a], 1) for a in ids}

    def init_stack(net, key):
        """Initialise one encoder/core/head stack."""
        ke, kc, kh = jax.random.split(key, 3)
        return {
            "encoder": net["encoder"].init(ke),
            "core": net["core"].init(kc),
            "head": net["head"].init(kh),
        }

    def init(key):
        """Initialise actor/critic stacks (shared across agents if homogeneous)."""
        ka, kc = jax.random.split(key)
        if share:
            return {
                "actor": {"shared": init_stack(actors[ids[0]], ka)},
                "critic": {"shared": init_stack(critics[ids[0]], kc)},
            }
        kas = jax.random.split(ka, len(ids))
        kcs = jax.random.split(kc, len(ids))
        return {
            "actor": {a: init_stack(actors[a], k) for a, k in zip(ids, kas)},
            "critic": {a: init_stack(critics[a], k) for a, k in zip(ids, kcs)},
        }

    class _Net:
        """step/unroll faces of one recurrent network family (actor or critic)."""

        def __init__(self, nets, group):
            self.nets, self.group = nets, group

        def _p(self, params, agent):
            sub = params[self.group]
            return sub["shared"] if share else sub[agent]

        def step(self, params, agent, h, x, reset=None):
            """One act-time step: ``(h, x) -> (h, head_output)``."""
            net, p = self.nets[agent], self._p(params, agent)
            z = net["encoder"].apply(p["encoder"], x)
            h, y = net["core"].step(p["core"], h, z, reset)
            return h, net["head"].apply(p["head"], y)

        def unroll(self, params, agent, h, xs, resets):
            # encoder/head are pointwise: apply outside the scan, scan the core
            """BPTT over ``(T, B, ...)`` inputs with FIRST-row resets."""
            net, p = self.nets[agent], self._p(params, agent)
            z = net["encoder"].apply(p["encoder"], xs)
            h, ys = net["core"].unroll(p["core"], h, z, resets)
            return h, net["head"].apply(p["head"], ys)

    return ids, num_actions, init, _Net(actors, "actor"), _Net(critics, "critic")


def make_recurrent_ppo_system(env, cfg: PPOConfig, centralised: bool, name: str) -> System:
    """Build a recurrent PPO `System` (rec-IPPO or rec-MAPPO by critic input)."""
    spec: EnvSpec = env.spec()
    ids, num_actions, init_params, actor, critic = make_recurrent_ppo_networks(
        env, cfg, centralised
    )
    hidden = cfg.hidden_sizes[-1]
    opt = optim.chain(
        optim.clip_by_global_norm(cfg.max_grad_norm),
        optim.adamw(cfg.learning_rate),
    )

    def critic_obs(obs, state, agent):
        """The critic input: global state (rec-MAPPO) or own obs (rec-IPPO)."""
        return state if centralised else obs[agent]

    def init_train(key):
        """Initialise the `TrainState` (params, targets, optimizer, steps)."""
        params = init_params(key)
        return TrainState(params, params, opt.init(params), jnp.zeros((), jnp.int32))

    def initial_carry(batch_shape):
        """The executor's initial memory for a ``batch_shape`` of envs."""
        zeros = lambda: {a: jnp.zeros((*batch_shape, hidden)) for a in ids}
        return Carry(hidden={"actor": zeros(), "critic": zeros()})

    # ------------------------------------------------------------ executor

    def select_actions(train: TrainState, obs, state, carry, key, training=True):
        """One recurrent act step; threads the typed `Carry` through.

        In training mode the *incoming* carry rides along in
        ``extras["carry_in"]`` so BPTT windows can re-run from the exact
        executor memory (the runner has already zeroed it at auto-reset
        FIRST boundaries, so stored FIRST rows carry zeros).  Greedy
        execution (``training=False``) threads only the actor cores.
        """
        params = train.params
        h_actor, h_critic = dict(carry.hidden["actor"]), dict(carry.hidden["critic"])
        if not training:
            actions = {}
            for a in ids:
                h_actor[a], lg = actor.step(params, a, h_actor[a], obs[a])
                actions[a] = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            return actions, Carry(hidden={"actor": h_actor, "critic": h_critic}), {}
        actions, logps, values = {}, {}, {}
        for i, a in enumerate(ids):
            h_actor[a], lg = actor.step(params, a, h_actor[a], obs[a])
            act_ = jax.random.categorical(jax.random.fold_in(key, i), lg)
            lp = jax.nn.log_softmax(lg)
            logps[a] = jnp.take_along_axis(lp, act_[..., None], axis=-1)[..., 0]
            actions[a] = act_.astype(jnp.int32)
            h_critic[a], v = critic.step(
                params, a, h_critic[a], critic_obs(obs, state, a)
            )
            values[a] = v[..., 0]
        new_carry = Carry(hidden={"actor": h_actor, "critic": h_critic})
        extras = {"logp": logps, "value": values, "carry_in": carry}
        return actions, new_carry, extras

    # ------------------------------------------------------------- trainer

    gae = _make_gae(cfg, ids)

    def loss_fn(params, mb):
        """PPO loss over full-length sequences (one BPTT re-run per net)."""
        total = 0.0
        resets = mb["resets"]
        for a in ids:
            h0 = mb["carry0"].hidden["actor"][a]
            _, lg = actor.unroll(params, a, h0, mb["obs"][a], resets)
            lp_all = jax.nn.log_softmax(lg)
            lp = jnp.take_along_axis(
                lp_all, mb["actions"][a][..., None], axis=-1
            )[..., 0]
            hc0 = mb["carry0"].hidden["critic"][a]
            _, v = critic.unroll(
                params, a, hc0, critic_obs(mb["obs"], mb["state"], a), resets
            )
            total = total + _ppo_surrogate(
                cfg, lp, lp_all, mb["logp"][a],
                mb["advantage"][a], v[..., 0], mb["returns"][a],
            )
        return total, {"loss": total}

    def update(train: TrainState, buffer, key):
        """Consume the rollout: GAE, then epochs of sequence minibatches."""
        traj: Transition = rollout_take(buffer)  # leaves (T, B, ...)
        T, B = traj.discount.shape
        resets = traj.step_type == StepType.FIRST  # (T, B)
        carry0 = window_start_carry(traj.extras, initial_carry, (B,))

        # Bootstrap value at T: replay the critic cores over the window from
        # the stored start carry (same params as act time — on-policy), then
        # one step on the final next-observation.  When the last row ended
        # an episode its discount is 0, so the (stale-memory) bootstrap for
        # the just-started episode is gated out of GAE entirely.
        last_obs = jax.tree_util.tree_map(lambda x: x[-1], traj.next_obs)
        last_state = traj.next_state[-1]
        last_values, curr_values = {}, {}
        for a in ids:
            h_t, v_seq = critic.unroll(
                train.params, a, carry0.hidden["critic"][a],
                critic_obs(traj.obs, traj.state, a), resets,
            )
            _, v = critic.step(
                train.params, a, h_t, critic_obs(last_obs, last_state, a)
            )
            last_values[a] = v[..., 0]
            curr_values[a] = v_seq[..., 0]
        if cfg.use_vtrace:
            # off-policy correction for stale-snapshot actors: current
            # log-probs from an actor BPTT re-run over the stored window,
            # current values from the critic unroll above
            adv, ret = {}, {}
            disc = traj.discount * cfg.gamma
            for a in ids:
                _, lg = actor.unroll(
                    train.params, a, carry0.hidden["actor"][a],
                    traj.obs[a], resets,
                )
                curr_lp = jnp.take_along_axis(
                    jax.nn.log_softmax(lg), traj.actions[a][..., None], axis=-1
                )[..., 0]
                adv[a], ret[a] = vtrace_advantages(
                    curr_lp, traj.extras["logp"][a], curr_values[a],
                    last_values[a], traj.rewards[a], disc,
                    clip_rho=cfg.vtrace_clip_rho, clip_c=cfg.vtrace_clip_c,
                    lam=cfg.gae_lambda,
                )
        else:
            adv, ret = gae(traj, last_values)

        data = dict(
            obs=traj.obs,
            state=traj.state,
            actions=traj.actions,
            logp=traj.extras["logp"],
            advantage=adv,
            returns=ret,
            resets=resets,
        )
        # sequence minibatching: shuffle and split the env axis, keep time
        # intact. n_mb is the largest divisor of B up to cfg.num_minibatches
        # so every collected sequence trains each epoch (no silent drops)
        # and the B=1 python loop still gets one minibatch.
        n_mb = max(
            m for m in range(1, min(cfg.num_minibatches, B) + 1) if B % m == 0
        )
        mb_size = B // n_mb

        def epoch(carry, _):
            """One PPO epoch: shuffle, split into minibatches, scan `mb_step`."""
            params, opt_state, key = carry
            key, kp = jax.random.split(key)
            perm = jax.random.permutation(kp, B)[: n_mb * mb_size]
            # (T, B, ...) -> (n_mb, T, mb_size, ...)
            mbs = jax.tree_util.tree_map(
                lambda x: jnp.moveaxis(
                    x[:, perm].reshape((T, n_mb, mb_size) + x.shape[2:]), 1, 0
                ),
                data,
            )
            # window-start carries ride the same env shuffle: (n_mb, mb_size, H)
            mbs["carry0"] = jax.tree_util.tree_map(
                lambda x: x[perm].reshape((n_mb, mb_size) + x.shape[1:]), carry0
            )

            def mb_step(carry, mb):
                """One minibatch gradient step (optionally pmean over the mesh)."""
                params, opt_state = carry
                (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                if cfg.distributed_axis:
                    grads = jax.lax.pmean(grads, cfg.distributed_axis)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = optim.apply_updates(params, updates)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                mb_step, (params, opt_state), mbs
            )
            return (params, opt_state, key), jnp.mean(losses)

        (params, opt_state, _), losses = jax.lax.scan(
            epoch, (train.params, train.opt_state, key), None, length=cfg.epochs
        )
        new_train = TrainState(params, params, opt_state, train.steps + 1)
        return new_train, rollout_reset(buffer), {"loss": jnp.mean(losses)}

    # ------------------------------------------------------------- dataset

    def example_transition():
        """A zero `Transition` fixing the buffer's shapes and dtypes."""
        obs = {a: jnp.zeros(spec.observations[a].shape) for a in ids}
        scalars = {a: jnp.zeros(()) for a in ids}
        return Transition(
            obs=obs,
            actions={a: jnp.zeros((), jnp.int32) for a in ids},
            rewards=dict(scalars),
            discount=jnp.zeros(()),
            next_obs=obs,
            state=jnp.zeros(spec.state.shape),
            next_state=jnp.zeros(spec.state.shape),
            # carry_in stores the full incoming Carry per step. Only row 0
            # is read back (window_start_carry); the per-step rows buy the
            # simple protocol invariant "memory rides Transition.extras"
            # at ~2*hidden floats per agent per step — revisit with a
            # window-start-only slot if rollout memory ever dominates.
            extras={
                "logp": dict(scalars),
                "value": dict(scalars),
                "carry_in": initial_carry(()),
            },
            step_type=jnp.zeros((), jnp.int32),
        )

    def init_buffer(num_envs: int):
        """A fresh experience buffer for ``num_envs`` parallel envs."""
        return rollout_init(example_transition(), cfg.rollout_len, num_envs)

    return System(
        env=env,
        spec=spec,
        init_train=init_train,
        update=update,
        select_actions=select_actions,
        initial_carry=initial_carry,
        init_buffer=init_buffer,
        observe=rollout_add,
        can_sample=lambda buf: rollout_ready(buf, cfg.rollout_len),
        name=name,
    )


# ------------------------------------------------------------ constructors


def make_ippo(env, cfg: PPOConfig = PPOConfig()) -> System:
    """Feed-forward IPPO: decentralised MLP critics on each agent's obs."""
    return make_ppo_system(env, cfg, centralised=False, name="ippo")


def make_mappo(env, cfg: PPOConfig = PPOConfig()) -> System:
    """Feed-forward MAPPO: centralised MLP critics on the global state."""
    return make_ppo_system(env, cfg, centralised=True, name="mappo")


def make_rec_ippo(env, cfg: PPOConfig = PPOConfig()) -> System:
    """Recurrent IPPO: GRU-core actors/critics on each agent's obs stream."""
    return make_recurrent_ppo_system(env, cfg, centralised=False, name="rec_ippo")


def make_rec_mappo(env, cfg: PPOConfig = PPOConfig()) -> System:
    """Recurrent MAPPO: GRU-core actors, centralised GRU critics on state."""
    return make_recurrent_ppo_system(env, cfg, centralised=True, name="rec_mappo")
