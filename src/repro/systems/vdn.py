"""VDN (Sunehag et al. 2017) — MADQN wrapped with additive mixing.

The paper's ``mixing.AdditiveMixing(architecture)`` module composition.
"""
from repro.core.modules.mixing import AdditiveMixing
from repro.systems.offpolicy import OffPolicyConfig, make_offpolicy_system


def make_vdn(env, cfg: OffPolicyConfig = OffPolicyConfig()):
    """Build VDN: agent Q-nets under additive value decomposition."""
    return make_offpolicy_system(env, cfg, mixer=AdditiveMixing(), name="vdn")
