"""QMIX (Rashid et al. 2018) — monotonic value-function factorisation.

MADQN wrapped with the state-conditioned hypernetwork mixer.
"""
from repro.core.modules.mixing import MonotonicMixing
from repro.systems.offpolicy import OffPolicyConfig, make_offpolicy_system


def make_qmix(
    env, cfg: OffPolicyConfig = OffPolicyConfig(), embed_dim: int = 32
):
    """Build QMIX: agent Q-nets under a monotonic hypernetwork mixer."""
    return make_offpolicy_system(
        env, cfg, mixer=MonotonicMixing(embed_dim=embed_dim), name="qmix"
    )
