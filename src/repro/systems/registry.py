"""The system registry: every algorithm family behind one constructor.

Mirrors ``repro.envs.REGISTRY``: a name -> `SystemEntry` table plus
``make_system(name, env, *, distributed_axis=None, **overrides)`` so the
launchers, the sweep and user code build any of the nine systems the same
way. Each entry declares the action-space regime the algorithm supports
(spec-driven compatibility checks replace string heuristics like
``"ddpg" in name``) and whether it requires homogeneous agents (shared
recurrent weights, as in DIAL).

``compatibility(system_name, env_name)`` answers whether a (system, env)
cell of the support matrix is runnable — and why not, when it isn't —
which is exactly what the ``eval_marl`` sweep writes into
``BENCH_eval.json``. ``make_pair`` builds the (env, system) pair, turning
on an env's continuous mode automatically when a continuous-control system
asks for it.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, Optional

from repro.envs import REGISTRY as ENV_REGISTRY
from repro.envs.api import DiscreteSpec, EnvSpec
from repro.systems.dial import DialConfig, make_dial
from repro.systems.maddpg import MaddpgConfig, make_mad4pg, make_maddpg
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.onpolicy import (
    PPOConfig,
    make_ippo,
    make_mappo,
    make_rec_ippo,
    make_rec_mappo,
)
from repro.systems.qmix import make_qmix
from repro.systems.rec_madqn import RecMadqnConfig, make_rec_madqn
from repro.systems.vdn import make_vdn


@dataclasses.dataclass(frozen=True)
class SystemEntry:
    """Registry row: how to build a system + what it declares to support."""

    factory: Callable[[Any, Any], Any]  # (env, cfg) -> System
    config_cls: type
    action_space: str = "discrete"      # "discrete" | "continuous"
    homogeneous_only: bool = False      # shared-weight recurrent systems
    description: str = ""


def _with(factory, **patch):
    return lambda env, cfg: factory(env, dataclasses.replace(cfg, **patch))


REGISTRY: Dict[str, SystemEntry] = {
    "madqn": SystemEntry(
        make_madqn, OffPolicyConfig,
        description="independent double-DQN learners",
    ),
    "madqn-fp": SystemEntry(
        _with(make_madqn, fingerprint=True), OffPolicyConfig,
        description="MADQN + policy-fingerprint replay stabilisation",
    ),
    "vdn": SystemEntry(
        make_vdn, OffPolicyConfig,
        description="value decomposition (additive mixing)",
    ),
    "qmix": SystemEntry(
        make_qmix, OffPolicyConfig,
        description="monotonic hypernetwork mixing",
    ),
    "maddpg": SystemEntry(
        make_maddpg, MaddpgConfig, action_space="continuous",
        description="centralised-critic DDPG (continuous control)",
    ),
    "mad4pg": SystemEntry(
        make_mad4pg, MaddpgConfig, action_space="continuous",
        description="MADDPG with a C51 distributional critic",
    ),
    "ippo": SystemEntry(
        make_ippo, PPOConfig,
        description="independent PPO (decentralised critics)",
    ),
    "mappo": SystemEntry(
        make_mappo, PPOConfig,
        description="PPO with centralised critics (CTDE)",
    ),
    "rec_ippo": SystemEntry(
        make_rec_ippo, PPOConfig,
        description="recurrent IPPO (GRU memory cores, partial observability)",
    ),
    "rec_madqn": SystemEntry(
        make_rec_madqn, RecMadqnConfig,
        description="recurrent MADQN over R2D2 sequence replay "
        "(stored-carry windows, burn-in)",
    ),
    "rec_mappo": SystemEntry(
        make_rec_mappo, PPOConfig,
        description="recurrent MAPPO (GRU cores + centralised recurrent critics)",
    ),
    "dial": SystemEntry(
        make_dial, DialConfig, homogeneous_only=True,
        description="differentiable inter-agent communication",
    ),
    "rial": SystemEntry(
        _with(make_dial, protocol="rial"), DialConfig, homogeneous_only=True,
        description="RIAL baseline (Q-learned discrete channel)",
    ),
}


# ----------------------------------------------------- spec-driven checks


def env_action_space(spec: EnvSpec) -> str:
    """The env's action regime, read off its spec (not its name)."""
    kinds = {
        "discrete" if isinstance(s, DiscreteSpec) else "continuous"
        for s in spec.actions.values()
    }
    return kinds.pop() if len(kinds) == 1 else "mixed"

def env_is_homogeneous(spec: EnvSpec) -> bool:
    """True when every agent shares one (obs shape, action spec) signature."""
    sigs = {
        (spec.observations[a].shape, repr(spec.actions[a]))
        for a in spec.agent_ids
    }
    return len(sigs) == 1


def _support_reason(
    system_name: str,
    action_space: str,
    homogeneous_only: bool,
    spec: EnvSpec,
) -> Optional[str]:
    env_kind = env_action_space(spec)
    if env_kind != action_space:
        return (
            f"{system_name} supports {action_space} action spaces; "
            f"env has {env_kind} actions"
        )
    if homogeneous_only and not env_is_homogeneous(spec):
        return f"{system_name} requires homogeneous agents (shared weights)"
    return None


def check_support(system_name: str, spec: EnvSpec) -> Optional[str]:
    """None when the system supports this env spec, else the reason not."""
    entry = REGISTRY[system_name]
    return _support_reason(
        system_name, entry.action_space, entry.homogeneous_only, spec
    )


def _env_supports_continuous(env_name: str) -> bool:
    params = inspect.signature(ENV_REGISTRY[env_name]).parameters
    return "continuous" in params


def _env_kwargs_for(system_name: str, env_name: str, env_kwargs=None) -> dict:
    kwargs = dict(env_kwargs or {})
    if kwargs.get("continuous") and not _env_supports_continuous(env_name):
        raise ValueError(
            f"env {env_name!r} has no continuous-action mode "
            "(no `continuous` construction flag)"
        )
    entry = REGISTRY[system_name]
    if (
        entry.action_space == "continuous"
        and "continuous" not in kwargs
        and _env_supports_continuous(env_name)
    ):
        kwargs["continuous"] = True
    return kwargs


def compatibility(system_name: str, env_name: str, env_kwargs=None) -> Optional[str]:
    """None when the (system, env) cell is runnable, else the reason not."""
    if system_name not in REGISTRY:
        raise KeyError(
            f"unknown system {system_name!r}; registered: {sorted(REGISTRY)}"
        )
    if env_name not in ENV_REGISTRY:
        raise KeyError(
            f"unknown env {env_name!r}; registered: {sorted(ENV_REGISTRY)}"
        )
    try:
        kwargs = _env_kwargs_for(system_name, env_name, env_kwargs)
    except ValueError as e:
        return str(e)
    spec = ENV_REGISTRY[env_name](**kwargs).spec()
    return check_support(system_name, spec)


# ------------------------------------------------------------ constructors


def make_system(name: str, env, *, distributed_axis: Optional[str] = None, **overrides):
    """Build a registered system on ``env`` (the `repro.envs.make_env` twin).

    ``overrides`` are fields of the entry's config dataclass (e.g.
    ``make_system("ippo", env, rollout_len=64)``); ``distributed_axis``
    wires gradient pmean for the sharded runner.
    """
    if name not in REGISTRY:
        raise KeyError(f"unknown system {name!r}; registered: {sorted(REGISTRY)}")
    entry = REGISTRY[name]
    # pre-build: the factory itself would crash on a mismatched spec
    reason = check_support(name, env.spec())
    if reason is not None:
        raise ValueError(f"incompatible system/env: {reason}")
    if distributed_axis is not None:
        overrides = dict(overrides, distributed_axis=distributed_axis)
    cfg = entry.config_cls(**overrides)
    system = entry.factory(env, cfg)
    # post-build: the System's own declaration must agree with its entry
    # (System.action_space is the run-time truth; the entry mirrors it so
    # `compatibility` can answer without building)
    reason = _support_reason(
        name, system.action_space, entry.homogeneous_only, system.spec
    )
    if reason is not None:
        raise ValueError(f"incompatible system/env: {reason}")
    return system


def make_pair(
    system_name: str,
    env_name: str,
    *,
    distributed_axis: Optional[str] = None,
    env_kwargs: Optional[dict] = None,
    **overrides,
):
    """Build (env, system) by name, auto-selecting the env's action mode.

    A continuous-control system turns on the env's ``continuous=True``
    construction flag when the env supports one (spec-checked afterwards).
    """
    kwargs = _env_kwargs_for(system_name, env_name, env_kwargs)
    env = ENV_REGISTRY[env_name](**kwargs)
    system = make_system(
        system_name, env, distributed_axis=distributed_axis, **overrides
    )
    return env, system
