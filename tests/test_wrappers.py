"""The composable wrapper stack: obs transforms, auto-reset, episode stats."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.envs import (
    AgentIdObs,
    AutoReset,
    ConcatObsState,
    EpisodeStats,
    MatrixGame,
    Spread,
    make_env,
)
from repro.envs.api import StepType
from repro.envs.wrappers import AutoResetState, replace_reset_keys


def _zeros_actions(env):
    return {a: jnp.asarray(0, jnp.int32) for a in env.agent_ids}


# ------------------------------------------------------------- AgentIdObs


def test_agent_id_obs_appends_one_hot():
    raw = Spread(num_agents=3)
    env = AgentIdObs(raw)
    spec, raw_spec = env.spec(), raw.spec()
    n = raw_spec.num_agents
    for a in spec.agent_ids:
        assert spec.observations[a].shape[0] == raw_spec.observations[a].shape[0] + n
    _, ts = env.reset(jax.random.key(0))
    _, raw_ts = raw.reset(jax.random.key(0))
    for i, a in enumerate(spec.agent_ids):
        ob = np.asarray(ts.observation[a])
        np.testing.assert_array_equal(ob[:-n], np.asarray(raw_ts.observation[a]))
        np.testing.assert_array_equal(ob[-n:], np.eye(n)[i])


# --------------------------------------------------------- ConcatObsState


def test_concat_obs_state_matches_observations():
    env = ConcatObsState(AgentIdObs(Spread(num_agents=2)))
    spec = env.spec()
    assert spec.state.shape[0] == sum(
        spec.observations[a].shape[0] for a in spec.agent_ids
    )
    state, ts = env.reset(jax.random.key(1))
    gs = np.asarray(env.global_state(state))
    manual = np.concatenate(
        [np.asarray(ts.observation[a]) for a in spec.agent_ids]
    )
    np.testing.assert_array_equal(gs, manual)


# -------------------------------------------------------------- AutoReset


def test_auto_reset_preserves_terminal_reward():
    """The merged boundary timestep carries the terminal step's reward."""
    raw = MatrixGame(horizon=3)
    env = AutoReset(raw)
    state, ts = env.reset(jax.random.key(0))
    acts = _zeros_actions(env)
    expected = float(raw.payoff[0, 0])  # joint action (0, 0) every step
    for t in range(1, 4):
        state, ts = env.step(state, acts)
        assert float(ts.reward["agent_0"]) == expected
    # step 3 terminated the inner env: merged FIRST, terminal discount
    assert int(ts.step_type) == StepType.FIRST
    assert float(ts.discount) == 0.0
    # and the stream continues into episode 2
    state, ts = env.step(state, acts)
    assert int(ts.step_type) == StepType.MID


def test_auto_reset_vmaps_across_copies():
    env = AutoReset(make_env("lbf", grid_size=5, num_food=2, horizon=4))
    keys = jax.random.split(jax.random.key(2), 3)
    state, ts = jax.vmap(env.reset)(keys)
    acts = {
        a: jnp.zeros((3,), jnp.int32) for a in env.agent_ids
    }
    step = jax.jit(jax.vmap(env.step))
    for _ in range(5):
        state, ts = step(state, acts)
    # noop-only play always runs to the horizon: all copies crossed exactly
    # one boundary at step 4 and are mid-episode again at step 5
    assert (np.asarray(ts.step_type) == StepType.MID).all()


def test_replace_reset_keys_controls_reset_stream():
    """Runners pin auto-reset randomness by swapping the stored key."""
    env = EpisodeStats(AutoReset(Spread(num_agents=2, horizon=1)))
    state, _ = env.reset(jax.random.key(3))
    forced = jax.random.key(42)
    state = replace_reset_keys(state, forced)
    assert isinstance(state.inner, AutoResetState)
    # horizon=1: the next step auto-resets using exactly `forced`
    state, ts = env.step(state, _zeros_actions(env))
    _, expected_ts = env.env.env.reset(forced)
    for a in env.agent_ids:
        np.testing.assert_array_equal(
            np.asarray(ts.observation[a]), np.asarray(expected_ts.observation[a])
        )


# ----------------------------------------------------------- EpisodeStats


def test_episode_stats_over_raw_env():
    """Over a raw env, stats publish on LAST and match a manual sum."""
    env = EpisodeStats(MatrixGame(horizon=4))
    state, ts = env.reset(jax.random.key(0))
    acts = _zeros_actions(env)
    total = 0.0
    while int(ts.step_type) != StepType.LAST:
        state, ts = env.step(state, acts)
        total += float(ts.reward["agent_0"])
    assert float(state.last_returns["agent_0"]) == total
    assert int(state.last_length) == 4
    # accumulators rewound for the next episode
    assert float(state.returns["agent_0"]) == 0.0
    assert int(state.length) == 0


def test_episode_stats_over_auto_reset():
    """Composed outside AutoReset, stats publish at the fused boundary."""
    env = EpisodeStats(AutoReset(MatrixGame(horizon=3)))
    state, _ = env.reset(jax.random.key(0))
    acts = _zeros_actions(env)
    per_step = float(MatrixGame().payoff[0, 0])
    for _ in range(3):  # third step is the fused boundary
        state, ts = env.step(state, acts)
    assert int(ts.step_type) == StepType.FIRST
    assert float(state.last_returns["agent_0"]) == 3 * per_step
    assert int(state.last_length) == 3
    # second episode accumulates from zero
    state, ts = env.step(state, acts)
    assert float(state.returns["agent_0"]) == per_step
    assert int(state.length) == 1


# ----------------------------------------- runners on the wrapped new envs


def test_train_anakin_runs_fused_on_new_envs():
    """Both new envs step inside the fused Anakin scan and report episode
    stats through the wrapper stack (no per-step host round trip)."""
    from repro.core.system import train_anakin
    from repro.systems import make_pair

    kwargs = {
        "robot_warehouse": {"horizon": 8, "grid_size": 6, "num_shelves": 4},
        "lbf": {"horizon": 8, "grid_size": 6, "num_food": 2},
    }
    for env_name in ("robot_warehouse", "lbf"):
        _, system = make_pair(
            "ippo", env_name, rollout_len=8, epochs=1, num_minibatches=1,
            env_kwargs=kwargs[env_name],
        )
        st, metrics = train_anakin(system, jax.random.key(0), 24, num_envs=4)
        assert int(st.train.steps) >= 1, env_name
        for k in ("reward", "done_frac", "episode_return"):
            assert np.isfinite(np.asarray(metrics[k])).all(), (env_name, k)
        # episodes end within the horizon, so boundaries must have fired
        done = np.asarray(metrics["done_frac"])
        assert done.sum() >= 2.0, env_name
        if env_name == "robot_warehouse":
            # rware ends on the horizon only: boundaries arrive in lockstep
            assert (done[7::8] == 1.0).all()
            assert (np.delete(done, np.s_[7::8]) == 0.0).all()


def test_run_environment_loop_on_new_env():
    from repro.core.system import run_environment_loop
    from repro.systems import make_pair

    _, system = make_pair(
        "madqn", "lbf",
        buffer_capacity=64, min_replay=8, batch_size=4,
        env_kwargs={"horizon": 6, "grid_size": 5, "num_food": 2},
    )
    _, _, ev = run_environment_loop(system, jax.random.key(0), num_episodes=3)
    assert ev.episode_return.shape == (3,)
    assert (ev.episode_length >= 1).all() and (ev.episode_length <= 6).all()
