"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "scale": jnp.ones((5,), jnp.bfloat16),
        "steps": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path)
    save_checkpoint(d, 42, tree)
    assert latest_step(d) == 42
    target = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = restore_checkpoint(d, 42, target)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32)),
        tree,
        restored,
    )
    assert restored["scale"].dtype == jnp.bfloat16


def test_latest_step_picks_max(tmp_path):
    d = str(tmp_path)
    tree = {"x": jnp.zeros(())}
    for s in (1, 10, 5):
        save_checkpoint(d, s, tree)
    assert latest_step(d) == 10


def test_missing_key_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"x": jnp.zeros(())})
    try:
        restore_checkpoint(d, 1, {"y": jnp.zeros(())})
        assert False, "expected KeyError"
    except KeyError:
        pass


def test_model_params_roundtrip(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import model as M

    cfg = get_smoke_config("internlm2-1.8b")
    params = M.init_model(jax.random.key(0), cfg)
    d = str(tmp_path)
    save_checkpoint(d, 3, params)
    target = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored = restore_checkpoint(d, 3, target)
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
