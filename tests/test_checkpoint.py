"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "scale": jnp.ones((5,), jnp.bfloat16),
        "steps": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path)
    save_checkpoint(d, 42, tree)
    assert latest_step(d) == 42
    target = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = restore_checkpoint(d, 42, target)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32)),
        tree,
        restored,
    )
    assert restored["scale"].dtype == jnp.bfloat16


def test_latest_step_picks_max(tmp_path):
    d = str(tmp_path)
    tree = {"x": jnp.zeros(())}
    for s in (1, 10, 5):
        save_checkpoint(d, s, tree)
    assert latest_step(d) == 10


def test_missing_key_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"x": jnp.zeros(())})
    try:
        restore_checkpoint(d, 1, {"y": jnp.zeros(())})
        assert False, "expected KeyError"
    except KeyError:
        pass


def test_model_params_roundtrip(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import model as M

    cfg = get_smoke_config("internlm2-1.8b")
    params = M.init_model(jax.random.key(0), cfg)
    d = str(tmp_path)
    save_checkpoint(d, 3, params)
    target = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored = restore_checkpoint(d, 3, target)
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def _assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        ),
        a,
        b,
    )


def _marl_system_state(name, key):
    """A real trained `SystemState` (typed Carry + optimizer state)."""
    from repro.bench.throughput import smoke_overrides
    from repro.core.system import train_anakin
    from repro.systems.registry import make_pair

    _, system = make_pair(name, "matrix_game", **smoke_overrides(name))
    st, _ = train_anakin(system, key, 4, 2)
    return system, st


def _roundtrip_system_state(name, tmp_path):
    """save -> restore a full MARL SystemState; every leaf bitwise equal.

    Covers the leaf kinds training actually produces: optimizer state
    (adam moments), the typed recurrent `Carry`, env state, timesteps and
    the typed PRNG key (saved as raw key data, rewrapped on restore).
    """
    system, st = _marl_system_state(name, jax.random.key(0))
    d = str(tmp_path)
    save_checkpoint(d, 11, st)
    target = jax.tree_util.tree_map(
        lambda x: x, st  # same structure; values get replaced on restore
    )
    restored = restore_checkpoint(d, 11, target)
    _assert_trees_equal(
        jax.tree_util.tree_map(
            lambda x: jax.random.key_data(x) if hasattr(x, "dtype")
            and jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key) else x,
            st,
        ),
        jax.tree_util.tree_map(
            lambda x: jax.random.key_data(x) if hasattr(x, "dtype")
            and jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key) else x,
            restored,
        ),
    )
    assert len(jax.tree_util.tree_leaves(restored.train.opt_state)) > 0
    return system, st, restored


def test_marl_system_state_roundtrip_feedforward(tmp_path):
    _roundtrip_system_state("madqn", tmp_path)


def test_marl_system_state_roundtrip_recurrent(tmp_path):
    system, st, restored = _roundtrip_system_state("rec_ippo", tmp_path)
    # the typed Carry must round-trip with its rows intact
    assert len(jax.tree_util.tree_leaves(restored.carry.hidden)) > 0
    _assert_trees_equal(st.carry, restored.carry)


def test_restored_system_state_resumes_training_bitwise(tmp_path):
    """Training from a restored state == training straight through.

    The strongest form of the round trip: restore mid-run, continue, and
    land bitwise where the uninterrupted run lands.
    """
    from repro.bench.throughput import smoke_overrides
    from repro.core.system import make_anakin, train_anakin
    from repro.systems.registry import make_pair

    _, system = make_pair("madqn", "matrix_game", **smoke_overrides("madqn"))
    key = jax.random.key(2)
    st_mid, _ = train_anakin(system, key, 3, 2)

    d = str(tmp_path)
    save_checkpoint(d, 3, st_mid)
    restored = restore_checkpoint(d, 3, st_mid)
    restored = jax.tree_util.tree_map(jnp.asarray, restored)

    program = make_anakin(system, 3, 2)
    cont_a = jax.block_until_ready(program.fused(st_mid))[0]
    cont_b = jax.block_until_ready(program.fused(restored))[0]
    _assert_trees_equal(cont_a.train.params, cont_b.train.params)


def test_serve_policy_restores_into_fresh_system_state(tmp_path):
    """The serve-side hand-off: checkpointed trainer in a fresh state."""
    from repro.bench.throughput import smoke_overrides
    from repro.serve import fresh_system_state, load_policy, save_policy

    system, st = _marl_system_state("rec_ippo", jax.random.key(1))
    d = str(tmp_path / "pol")
    save_policy(
        d, "rec_ippo", "matrix_game", st.train,
        config_overrides=smoke_overrides("rec_ippo"), step=4,
    )
    _, system2, train2 = load_policy(d)
    fresh = fresh_system_state(system2, train2, jax.random.key(9), 2)
    _assert_trees_equal(st.train.params, fresh.train.params)
    _assert_trees_equal(st.train.opt_state, fresh.train.opt_state)
    # fresh episodes + zero memory around the restored trainer
    assert int(fresh.train.steps) == int(st.train.steps)
