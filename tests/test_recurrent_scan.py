"""Fused recurrent scan: op parity, gradients, core equivalence, wiring.

Covers the `repro.kernels.recurrent_scan` triplet (XLA and Pallas-interpret
paths vs the sequential oracle), the `LinearScannedRNN` core against a
step-by-step scan across reset patterns, the end-to-end system wiring
(``recurrent_core="linear"`` in rec-IPPO and no-comm DIAL), and the
import-never-compiles guarantee of `repro.kernels` (docs/KERNELS.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.recurrent_scan.ops import linear_recurrent_scan
from repro.kernels.recurrent_scan.ref import linear_recurrence_ref
from repro.nn.recurrent import LinearScannedRNN, make_core


def _inputs(T, batch, D, seed=0, with_reset=True, h0_zero=False):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(jax.nn.sigmoid(rng.normal(size=(T, *batch, D))), jnp.float32)
    b = jnp.asarray(rng.normal(size=(T, *batch, D)) * 0.1, jnp.float32)
    h0 = (
        jnp.zeros((*batch, D))
        if h0_zero
        else jnp.asarray(rng.normal(size=(*batch, D)), jnp.float32)
    )
    reset = (
        jnp.asarray(rng.random(size=(T, *batch)) < 0.3) if with_reset else None
    )
    return a, b, h0, reset


# ---------------------------------------------------------------- op parity


@pytest.mark.parametrize(
    "T,batch,D",
    [
        (7, (3,), 5),      # odd T, odd D (padding on both axes)
        (33, (2, 4), 16),  # two batch dims, odd T
        (128, (4,), 32),   # T a chunk multiple
        (1, (2,), 8),      # single step
    ],
)
@pytest.mark.parametrize("with_reset", [False, True])
def test_op_matches_ref_xla_path(T, batch, D, with_reset):
    a, b, h0, reset = _inputs(T, batch, D, with_reset=with_reset)
    out = linear_recurrent_scan(a, b, h0, reset)
    ref = linear_recurrence_ref(a, b, h0, reset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize(
    "T,batch,D",
    [
        (7, (3,), 5),
        (64, (2,), 16),
        (33, (2, 4), 16),
    ],
)
def test_op_matches_ref_pallas_interpret(T, batch, D):
    a, b, h0, reset = _inputs(T, batch, D, seed=1)
    out = linear_recurrent_scan(a, b, h0, reset, interpret=True)
    ref = linear_recurrence_ref(a, b, h0, reset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_op_gradients_match_ref():
    a, b, h0, reset = _inputs(17, (3,), 8, seed=2)
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(17, 3, 8)), jnp.float32)

    def loss_op(a, b, h0):
        return jnp.sum(linear_recurrent_scan(a, b, h0, reset) * g)

    def loss_ref(a, b, h0):
        return jnp.sum(linear_recurrence_ref(a, b, h0, reset) * g)

    got = jax.grad(loss_op, argnums=(0, 1, 2))(a, b, h0)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(a, b, h0)
    for name, x, y in zip(("da", "db", "dh0"), got, want):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), atol=1e-4, rtol=1e-4, err_msg=name
        )


# ---------------------------------------------------- core vs step-scan ref


def _unroll_by_steps(core, params, carry, xs, resets):
    """The oracle unroll: `core.step` applied one row at a time."""
    def body(h, inp):
        x, r = inp
        return core.step(params, h, x, r)

    if resets is None:
        resets = jnp.zeros(xs.shape[:-1], bool)
    return jax.lax.scan(body, carry, (xs, resets))


@pytest.mark.parametrize(
    "pattern",
    ["none", "all", "mid_window", "random"],
)
@pytest.mark.parametrize("T", [5, 16, 33])
def test_linear_core_unroll_matches_step_scan(pattern, T):
    B, in_dim, hidden = 4, 6, 12
    core = LinearScannedRNN(in_dim, hidden)
    params = core.init(jax.random.key(0))
    rng = np.random.default_rng(4)
    xs = jnp.asarray(rng.normal(size=(T, B, in_dim)), jnp.float32)
    # stored carry rows: BPTT windows open from the executor's saved state
    carry = jnp.asarray(rng.normal(size=(B, hidden)), jnp.float32)
    resets = {
        "none": None,
        "all": jnp.ones((T, B), bool),
        "mid_window": jnp.zeros((T, B), bool).at[T // 2].set(True),
        "random": jnp.asarray(rng.random(size=(T, B)) < 0.25),
    }[pattern]
    final_f, hs_f = core.unroll(params, carry, xs, resets)
    final_s, hs_s = _unroll_by_steps(core, params, carry, xs, resets)
    np.testing.assert_allclose(
        np.asarray(hs_f), np.asarray(hs_s), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(final_f), np.asarray(final_s), atol=1e-5, rtol=1e-5
    )


def test_make_core_registry():
    from repro.nn.recurrent import ScannedRNN

    assert isinstance(make_core("gru", 4, 8), ScannedRNN)
    assert isinstance(make_core("linear", 4, 8), LinearScannedRNN)
    with pytest.raises(ValueError, match="unknown recurrent core"):
        make_core("lstm", 4, 8)


# ------------------------------------------------------------ system wiring


@pytest.mark.slow
def test_rec_ippo_linear_core_trains():
    from repro.core.system import train_anakin
    from repro.systems.registry import make_pair

    _, system = make_pair(
        "rec_ippo", "matrix_game", recurrent_core="linear",
        hidden_sizes=(16, 16), rollout_len=8, epochs=1, num_minibatches=2,
    )
    state, metrics = train_anakin(
        system, jax.random.PRNGKey(0), num_iterations=20, num_envs=4
    )
    assert jnp.isfinite(metrics["episode_return"]).all()


@pytest.mark.slow
def test_dial_no_comm_linear_core_trains():
    from repro.core.system import train_anakin
    from repro.systems.registry import make_pair

    _, system = make_pair(
        "dial", "switch_game", use_comm=False, recurrent_core="linear",
        hidden_dim=16,
    )
    assert system.name == "rec-madqn"
    state, metrics = train_anakin(
        system, jax.random.PRNGKey(0), num_iterations=20, num_envs=4
    )
    assert jnp.isfinite(metrics["episode_return"]).all()


# ------------------------------------------- import-never-compiles guarantee


def test_kernels_import_is_safe_without_accelerator():
    """Importing repro.kernels must never trigger Pallas compilation.

    The package guard (`repro.kernels.default_interpret`) routes kernels
    away from the Mosaic compiler off-TPU, so the import and a small op
    call both succeed on a CPU-only box — the satellite-6 smoke test.
    """
    import repro.kernels as K

    assert set(K.__all__) >= {
        "default_interpret", "flash_attention", "fused_softmax_xent",
        "linear_recurrent_scan", "selective_scan",
    }
    interp = K.default_interpret()
    assert interp == (jax.default_backend() != "tpu")
    # a tiny call through the default dispatch must work on any backend
    a, b, h0, reset = _inputs(4, (2,), 3, seed=5)
    out = K.linear_recurrent_scan(a, b, h0, reset)
    assert out.shape == (4, 2, 3)
    assert bool(jnp.isfinite(out).all())
