"""Mixing-module properties: VDN additivity, QMIX monotonicity."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.modules.mixing import AdditiveMixing, MonotonicMixing


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 6),
    qs=st.lists(st.floats(-10, 10), min_size=2, max_size=6),
)
def test_vdn_is_exact_sum(n, qs):
    qs = (qs + [0.0] * n)[:n]
    mixer = AdditiveMixing()
    params = mixer.init(jax.random.key(0), n, 4)
    out = mixer.apply(params, jnp.asarray(qs), jnp.zeros((4,)))
    # fp32 summation vs python float64: absolute tolerance required
    np.testing.assert_allclose(float(out), np.float32(qs).sum(), rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 5),
    state_dim=st.integers(1, 8),
)
def test_qmix_monotone_in_agent_qs(seed, n, state_dim):
    """dQ_tot/dQ_i >= 0 for every agent — the QMIX representational guarantee."""
    mixer = MonotonicMixing(embed_dim=8, hypernet_hidden=16)
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    params = mixer.init(k1, n, state_dim)
    qs = jax.random.normal(k2, (n,)) * 5
    state = jax.random.normal(k3, (state_dim,))
    grad = jax.grad(lambda q: mixer.apply(params, q, state))(qs)
    assert bool(jnp.all(grad >= -1e-6)), np.asarray(grad)


def test_qmix_uses_state():
    """Different global states must change the mixing (hypernet conditioning)."""
    mixer = MonotonicMixing(embed_dim=8)
    params = mixer.init(jax.random.key(0), 3, 4)
    qs = jnp.asarray([1.0, -2.0, 0.5])
    out1 = mixer.apply(params, qs, jnp.ones((4,)))
    out2 = mixer.apply(params, qs, -jnp.ones((4,)))
    assert abs(float(out1 - out2)) > 1e-6
