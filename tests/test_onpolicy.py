"""IPPO/MAPPO behaviour tests (System-API ports of the flagship systems)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.system import train_anakin
from repro.envs import MatrixGame, SpeakerListener
from repro.systems.onpolicy import PPOConfig, make_ippo, make_mappo

# Learning-curve milestones recorded from the seed (pre-System) IPPO
# implementation on matrix_game: PPOConfig(rollout_len=32, epochs=4,
# num_minibatches=2, entropy_coef=0.02, learning_rate=1e-3), seed 0,
# 150 updates x 16 envs -> per-update mean reward 2.281 (first 15) and
# 4.994 (last 15); the policy converges to the climbing game's safe
# equilibrium (payoff 5).
SEED_IPPO_FIRST15 = 2.281
SEED_IPPO_LAST15 = 4.994


def _per_update_rewards(system, key, num_updates, rollout_len, num_envs):
    """Train fused and fold per-iteration rewards into per-update means."""
    _, metrics = train_anakin(
        system, key, num_updates * rollout_len, num_envs=num_envs
    )
    r = np.asarray(metrics["reward"])
    return r.reshape(num_updates, rollout_len).mean(axis=-1)


def _milestone_system():
    return make_ippo(
        MatrixGame(horizon=10),
        PPOConfig(rollout_len=32, epochs=4, num_minibatches=2,
                  entropy_coef=0.02, learning_rate=1e-3),
    )


@functools.lru_cache(maxsize=1)
def _seed0_curve():
    """The milestone run (seed 0, 150 updates), shared by the tests below."""
    return _per_update_rewards(_milestone_system(), jax.random.key(0), 150, 32, 16)


def _assert_seed_milestones(r):
    late = r[-15:].mean()
    improvement = late - r[:15].mean()
    seed_improvement = SEED_IPPO_LAST15 - SEED_IPPO_FIRST15
    # converged within 10% of the seed's final level...
    assert abs(late - SEED_IPPO_LAST15) < 0.1 * abs(SEED_IPPO_LAST15), late
    # ...with at least half the seed's early->late improvement
    assert improvement > 0.5 * seed_improvement, (improvement, seed_improvement)


def test_ippo_learns_matrix_game():
    r = _seed0_curve()
    assert r[-15:].mean() > r[:15].mean() + 1.0, (r[:15].mean(), r[-15:].mean())


def test_ippo_parity_with_seed_curve():
    """The System-API port reproduces the seed implementation's curve.

    Same hyperparameters, seed and env-step budget as the recorded seed
    run: the port must hit the same milestones — clear early->late
    improvement and convergence to the safe equilibrium (payoff ~5).
    """
    _assert_seed_milestones(_seed0_curve())


def test_vmapped_seed_training_hits_seed_milestones():
    """Seed-vectorized training preserves the recorded IPPO milestones.

    Training seeds (0, 123) as one vmapped jit program, the seed-0 lane
    must be bitwise-identical to the serial seed-0 milestone run — the
    sweep's multi-seed vectorization is a pure execution change, not a
    semantic one.
    """
    keys = jnp.stack([jax.random.key(0), jax.random.key(123)])
    _, metrics = train_anakin(
        _milestone_system(), keys, 150 * 32, num_envs=16, num_seeds=2
    )
    lane0 = np.asarray(metrics["reward"])[0].reshape(150, 32).mean(axis=-1)
    np.testing.assert_array_equal(lane0, _seed0_curve())
    _assert_seed_milestones(lane0)


def test_mappo_improves_speaker_listener():
    env = SpeakerListener()
    system = make_mappo(
        env, PPOConfig(rollout_len=64, shared_weights=False, learning_rate=7e-4)
    )
    r = _per_update_rewards(system, jax.random.key(0), 120, 64, 16)
    assert r[-12:].mean() > r[:12].mean(), (r[:12].mean(), r[-12:].mean())


def test_ppo_per_agent_rewards_drive_gae():
    """General-sum rewards must not be collapsed to their mean.

    On a general-sum variant of the matrix game (agent_1's payoff is the
    negation of agent_0's), a mean-collapsing implementation sees the same
    (zero) reward stream for both variants below, so its updates would be
    bitwise identical; the per-agent GAE fix must produce different ones.
    (A plain nonzero-delta check would not do: AdamW weight decay moves
    params even at zero gradient.)
    """
    from repro.core.types import Transition

    env = MatrixGame(horizon=10)
    cfg = PPOConfig(rollout_len=8, epochs=1, num_minibatches=1, entropy_coef=0.0)
    system = make_ippo(env, cfg)
    train = system.init_train(jax.random.key(0))

    # hand-roll one rollout, storing antisymmetric per-agent rewards in one
    # buffer and their (identically zero) mean in the other
    buf_pa, buf_mean = system.init_buffer(4), system.init_buffer(4)
    key = jax.random.key(1)
    env_state, ts = jax.vmap(env.reset)(jax.random.split(key, 4))
    for _ in range(cfg.rollout_len):
        key, k_act = jax.random.split(key)
        gs = jax.vmap(env.global_state)(env_state)
        actions, _, extras = system.select_actions(
            train, ts.observation, gs, (), k_act
        )
        env_state, new_ts = jax.vmap(env.step)(env_state, actions)
        r0 = new_ts.reward["agent_0"]
        per_agent = {"agent_0": r0, "agent_1": -r0}      # general-sum
        collapsed = {a: (r0 - r0) / 2 for a in per_agent}  # their mean: 0

        def tr(rewards):
            return Transition(
                obs=ts.observation, actions=actions, rewards=rewards,
                discount=new_ts.discount, next_obs=new_ts.observation,
                state=gs, next_state=jax.vmap(env.global_state)(env_state),
                extras=extras, step_type=ts.step_type,
            )

        buf_pa = system.observe(buf_pa, tr(per_agent))
        buf_mean = system.observe(buf_mean, tr(collapsed))
        ts = new_ts
    assert bool(system.can_sample(buf_pa))
    train_pa, new_buf, _ = system.update(train, buf_pa, jax.random.key(2))
    train_mean, _, _ = system.update(train, buf_mean, jax.random.key(2))
    # the update consumed-and-reset the rollout...
    assert int(new_buf.t) == 0
    # ...and per-agent rewards produced a different update than their mean
    pa = jax.tree_util.tree_leaves(train_pa.params["actor"])
    mean = jax.tree_util.tree_leaves(train_mean.params["actor"])
    assert any(
        float(np.abs(np.asarray(p) - np.asarray(m)).max()) > 1e-6
        for p, m in zip(pa, mean)
    )


def test_centralised_critic_sees_state():
    """MAPPO's critic input dim == global state dim (CTDE wiring)."""
    env = MatrixGame()
    ippo = make_ippo(env, PPOConfig())
    mappo = make_mappo(env, PPOConfig())
    k = jax.random.key(0)
    ti = ippo.init_train(k)
    tm = mappo.init_train(k)
    spec = env.spec()
    # ippo critic first layer: obs dim; mappo: state dim
    wi = jax.tree_util.tree_leaves(ti.params["critic"])[1]
    wm = jax.tree_util.tree_leaves(tm.params["critic"])[1]
    assert wi.shape[0] == spec.observations["agent_0"].shape[0]
    assert wm.shape[0] == spec.state.shape[0]
