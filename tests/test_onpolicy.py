"""IPPO/MAPPO behaviour tests."""
import jax
import numpy as np

from repro.envs import MatrixGame, SpeakerListener
from repro.systems.onpolicy import PPOConfig, make_ippo, make_mappo


def test_ippo_learns_matrix_game():
    env = MatrixGame(horizon=10)
    system = make_ippo(env, PPOConfig(rollout_len=32, epochs=4, num_minibatches=2,
                                      entropy_coef=0.02, learning_rate=1e-3))
    train, metrics = system["train"](jax.random.key(0), num_updates=150, num_envs=16)
    r = np.asarray(metrics["reward"])
    assert r[-15:].mean() > r[:15].mean() + 1.0, (r[:15].mean(), r[-15:].mean())


def test_mappo_improves_speaker_listener():
    env = SpeakerListener()
    system = make_mappo(
        env, PPOConfig(rollout_len=64, shared_weights=False, learning_rate=7e-4)
    )
    train, metrics = system["train"](jax.random.key(0), num_updates=120, num_envs=16)
    r = np.asarray(metrics["reward"])
    assert r[-12:].mean() > r[:12].mean(), (r[:12].mean(), r[-12:].mean())


def test_centralised_critic_sees_state():
    """MAPPO's critic input dim == global state dim (CTDE wiring)."""
    env = MatrixGame()
    ippo = make_ippo(env, PPOConfig())
    mappo = make_mappo(env, PPOConfig())
    k = jax.random.key(0)
    ti = ippo["init_train"](k)
    tm = mappo["init_train"](k)
    spec = env.spec()
    # ippo critic first layer: obs dim; mappo: state dim
    wi = jax.tree_util.tree_leaves(ti.params["critic"])[1]
    wm = jax.tree_util.tree_leaves(tm.params["critic"])[1]
    assert wi.shape[0] == spec.observations["agent_0"].shape[0]
    assert wm.shape[0] == spec.state.shape[0]
