"""Multi-device tests (subprocess: jax locks device count at first init)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )


def test_distributed_executor_training_runs_and_syncs():
    """shard_map runner: params identical across executors (pmean sync)."""
    r = run_with_devices(
        """
        import jax, numpy as np
        from repro.envs import MatrixGame
        from repro.systems import make_madqn
        from repro.systems.offpolicy import OffPolicyConfig
        from repro.core.system import train_distributed

        from repro.launch.mesh import make_auto_mesh

        mesh = make_auto_mesh((4,), ("data",))
        env = MatrixGame(horizon=10)
        cfg = OffPolicyConfig(buffer_capacity=2000, min_replay=50, batch_size=16,
                              eps_decay_steps=500, distributed_axis="data")
        params, metrics, ev = train_distributed(make_madqn(env, cfg), jax.random.key(0),
                                                400, 4, mesh, eval_episodes=8)
        # out_specs P() asserts replication; reaching here means sync held
        r = np.asarray(metrics["reward"])
        assert np.isfinite(r).all()
        # fused per-device greedy eval: one mean return per executor
        ev = np.asarray(ev).ravel()
        assert ev.shape == (4,) and np.isfinite(ev).all()
        print("OK", r.ravel(), ev)
        """
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_async_runner_under_cpu_mesh():
    """2 actors on 2 forced CPU devices under an ambient mesh: the actors
    logical axis constraint engages (no-op correctness: results stay
    finite, chunks flow, nothing drops)."""
    r = run_with_devices(
        """
        import jax, numpy as np
        from repro.distributed import enter_mesh, make_async
        from repro.envs import make_env
        from repro.launch.mesh import make_auto_mesh
        from repro.systems.registry import make_system

        assert jax.local_device_count() == 2
        env = make_env("matrix_game")
        system = make_system("ippo", env, hidden_sizes=(32, 32), rollout_len=8,
                             epochs=1, num_minibatches=2)
        mesh = make_auto_mesh((2,), ("data",))
        with enter_mesh(mesh):
            st, m = make_async(system, 16, 4, 2)(jax.random.key(0))
        assert int(st.train.steps) > 0
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree_util.tree_leaves(st.train.params))
        assert float(np.asarray(m["dropped"])[-1]) == 0.0
        print("OK", int(st.train.steps))
        """,
        n=2,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


# The *exact* APIs the body calls: jax.make_mesh + jax.set_mesh +
# jax.sharding.AxisType.  Everything else in this file (shard_map, the
# legacy ambient-mesh context) runs on older jax and is tested above /
# in test_sharding.py.
_jax = __import__("jax")


@pytest.mark.skipif(
    not (
        hasattr(_jax, "set_mesh")
        and hasattr(_jax, "make_mesh")
        and hasattr(_jax.sharding, "AxisType")
    ),
    reason="body calls jax.make_mesh/jax.set_mesh/jax.sharding.AxisType",
)
def test_sharded_train_step_matches_single_device():
    """pjit'd LM train step on a 1x4 mesh == unsharded single-device step."""
    r = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.launch.steps import make_train_step
        from repro.models import model as M
        import dataclasses

        cfg = get_smoke_config("internlm2-1.8b")
        params = M.init_model(jax.random.key(0), cfg)
        opt, train_step = make_train_step(cfg, 1e-3)
        opt_state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}

        # single-device reference
        p1, o1, m1 = jax.jit(train_step)(params, opt_state, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            p2, o2, m2 = jax.jit(train_step)(params, opt_state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        a = jax.tree_util.tree_leaves(p1)[0]
        b = jax.tree_util.tree_leaves(p2)[0]
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=1e-5)
        print("OK", float(m1["loss"]))
        """
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
