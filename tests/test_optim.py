"""Optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import optim


def test_adamw_minimises_quadratic():
    opt = optim.adamw(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = optim.apply_updates(params, updates)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(1.0)
    grads = {"a": jnp.full((4,), 100.0), "b": jnp.full((3,), -100.0)}
    state = opt.init(grads)
    clipped, _ = opt.update(grads, state)
    assert float(optim.global_norm(clipped)) <= 1.0 + 1e-5


@settings(max_examples=20, deadline=None)
@given(st.floats(1e-4, 1e-1), st.integers(0, 2**31 - 1))
def test_sgd_step_direction(lr, seed):
    opt = optim.sgd(lr)
    g = jax.random.normal(jax.random.key(seed), (5,))
    state = opt.init({"w": jnp.zeros((5,))})
    updates, _ = opt.update({"w": g}, state)
    np.testing.assert_allclose(np.asarray(updates["w"]), -lr * np.asarray(g), rtol=1e-5)


def test_chain_composes():
    opt = optim.chain(optim.clip_by_global_norm(1.0), optim.sgd(1.0))
    params = {"w": jnp.zeros((2,))}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.asarray([30.0, 40.0])}, state, params)
    # after clip, norm 1; sgd lr=1 -> update = -clipped
    np.testing.assert_allclose(
        np.asarray(updates["w"]), [-0.6, -0.8], rtol=1e-5
    )


def test_schedules():
    from repro.optim import linear_warmup_cosine_decay

    sched = linear_warmup_cosine_decay(1.0, 10, 100, end_value=0.1)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-6
    assert abs(float(sched(100)) - 0.1) < 1e-6
    assert float(sched(55)) < 1.0


def test_adamw_mixed_dtype_tree():
    """Param trees mix bf16 matmul weights and fp32 norms (the LM case)."""
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16), "scale": jnp.ones((4,), jnp.float32)}
    opt = optim.adamw(1e-2)
    state = opt.init(params)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    updates, state = opt.update(grads, state, params)
    new = optim.apply_updates(params, updates)
    assert new["w"].dtype == jnp.bfloat16
    assert new["scale"].dtype == jnp.float32
