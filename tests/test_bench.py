"""Seed-vectorized training parity + the BENCH_speed throughput subsystem."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench.schema import (
    check_eval_full_matrix,
    check_eval_schema,
    check_serve_schema,
    check_serve_slice,
    check_speed_full_matrix,
    check_speed_schema,
)
from repro.bench.throughput import measure_seed_vectorization, to_markdown
from repro.core.system import seed_keys, train_anakin
from repro.envs import MatrixGame
from repro.eval import evaluate
from repro.eval.sweep import evaluate_on_env
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.onpolicy import PPOConfig, make_ippo, make_rec_ippo
from repro.systems.rec_madqn import RecMadqnConfig, make_rec_madqn
from repro.systems.vdn import make_vdn

REPO = pathlib.Path(__file__).resolve().parent.parent
CFG = OffPolicyConfig(buffer_capacity=500, min_replay=50, batch_size=16)


def _vdn():
    return make_vdn(MatrixGame(horizon=10), CFG)


def _ippo():
    return make_ippo(
        MatrixGame(horizon=10), PPOConfig(rollout_len=8, epochs=2, num_minibatches=2)
    )


def _rec_ippo():
    return make_rec_ippo(
        MatrixGame(horizon=10),
        PPOConfig(rollout_len=8, epochs=2, num_minibatches=2, hidden_sizes=(16, 16)),
    )


def _rec_madqn():
    return make_rec_madqn(
        MatrixGame(horizon=10),
        RecMadqnConfig(hidden_sizes=(16,), seq_len=4, burn_in=2,
                       buffer_capacity=64, batch_size=4, min_windows=4,
                       eps_decay_steps=50, target_update_period=5),
    )


def _lane(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


# ----------------------------------------------------- seed vectorization


def test_seed_keys_split_and_stacked():
    ks = seed_keys(jax.random.key(0), 3)
    assert ks.shape == (3,)
    stacked = jnp.stack([jax.random.key(s) for s in (5, 9)])
    out = seed_keys(stacked, 2)
    np.testing.assert_array_equal(
        jax.random.key_data(out), jax.random.key_data(stacked)
    )
    with pytest.raises(ValueError):
        seed_keys(stacked, 3)


@pytest.mark.parametrize(
    "make",
    [_vdn, _ippo, _rec_ippo, _rec_madqn],
    ids=["replay", "rollout", "recurrent", "seq_replay"],
)
def test_vmapped_seeds_bitwise_match_serial(make):
    """vmap-over-seeds training == N stacked serial runs, per-seed bitwise.

    Covers all three experience regimes (flat replay, rollout, sequence
    replay) plus the recurrent memory-core protocol (whose carries and
    stored ``extras["carry_in"]`` gain a lane axis); this also pins the
    hoisted update gate to the serial cadence in every regime (train.steps
    must agree — under a naive per-lane cond-as-select, or a seq-replay
    fill schedule that keyed on data, the update count would differ).
    """
    system = make()
    seeds = [0, 1, 2, 3]
    serial = [train_anakin(system, jax.random.key(s), 60, num_envs=4) for s in seeds]
    keys = jnp.stack([jax.random.key(s) for s in seeds])
    stv, mv = train_anakin(system, keys, 60, num_envs=4, num_seeds=4)
    assert mv["reward"].shape == (4, 60)
    for i in range(4):
        st_i, m_i = serial[i]
        np.testing.assert_array_equal(
            np.asarray(m_i["reward"]), np.asarray(mv["reward"])[i]
        )
        assert int(st_i.train.steps) == int(_lane(stv.train, i).steps)
        for a, b in zip(
            jax.tree_util.tree_leaves(st_i.train.params),
            jax.tree_util.tree_leaves(_lane(stv.train.params, i)),
        ):
            # params may drift a final ulp from XLA kernel-choice noise
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=0, atol=1e-6
            )


def test_vmapped_interleaved_eval_matches_serial():
    """Eval points inside the seed-batched jit reproduce serial lanes."""
    system = _vdn()
    ks = seed_keys(jax.random.key(3), 2)
    stv, mv, evv = train_anakin(
        system, ks, 40, num_envs=4,
        eval_every=20, eval_episodes=8, eval_num_envs=4, num_seeds=2,
    )
    assert mv["reward"].shape == (2, 40)
    assert evv.episode_return.shape == (2, 2, 8)  # (seeds, eval points, eps)
    for i in range(2):
        _, m_i, ev_i = train_anakin(
            system, ks[i], 40, num_envs=4,
            eval_every=20, eval_episodes=8, eval_num_envs=4,
        )
        np.testing.assert_array_equal(
            np.asarray(ev_i.episode_return), np.asarray(evv.episode_return)[i]
        )
        np.testing.assert_array_equal(
            np.asarray(m_i["reward"]), np.asarray(mv["reward"])[i]
        )


def test_evaluate_with_seed_axis_matches_standalone():
    system = _vdn()
    keys = jnp.stack([jax.random.key(s) for s in (0, 1)])
    trains = jax.vmap(system.init_train)(keys)
    batched = evaluate(
        system, trains, keys, num_episodes=6, num_envs=3, num_seeds=2
    )
    assert batched.episode_return.shape == (2, 6)
    for i in range(2):
        single = evaluate(
            system, _lane(trains, i), keys[i], num_episodes=6, num_envs=3
        )
        np.testing.assert_array_equal(
            np.asarray(single.episode_return),
            np.asarray(batched.episode_return)[i],
        )


def test_evaluate_num_seeds_must_match_batch():
    system = _vdn()
    keys = jnp.stack([jax.random.key(s) for s in (0, 1)])
    trains = jax.vmap(system.init_train)(keys)
    with pytest.raises(ValueError, match="num_seeds"):
        evaluate(system, trains, keys, num_episodes=4, num_envs=2, num_seeds=3)


def test_sweep_cell_matches_serial_per_seed_path():
    """The vectorized sweep cell reproduces the pre-vmap serial loop exactly
    (train per seed, then standalone eval with the same key derivation)."""
    system = _vdn()
    seeds = (0, 1)
    cell = evaluate_on_env(
        system, seeds, num_episodes=6, num_envs=3,
        train_iterations=40, train_num_envs=4,
    )
    assert cell["compatible"] and len(cell["returns"]) == len(seeds)
    for i, seed in enumerate(seeds):
        k_train, k_eval = jax.random.split(jax.random.key(seed))
        st, _ = train_anakin(system, k_train, 40, num_envs=4)
        ref = evaluate(system, st.train, k_eval, num_episodes=6, num_envs=3)
        np.testing.assert_array_equal(
            np.asarray(ref.episode_return), np.asarray(cell["returns"][i])
        )


# ------------------------------------------------------------- throughput


def test_measure_seed_vectorization_smoke():
    out = measure_seed_vectorization(_vdn(), num_seeds=2, iterations=8, num_envs=2)
    assert out["num_seeds"] == 2
    for k in ("serial_steps_per_sec", "vmapped_steps_per_sec", "speedup"):
        assert out[k] > 0


# ------------------------------------------------------- artifact schemas


def test_checked_in_artifacts_conform_to_schema():
    """The committed BENCH_* artifacts must match schema *and* coverage.

    The full checks additionally pin the matrix to the registry: every
    system (including the recurrent rec_ippo/rec_mappo rows) x env cell
    must be present in BENCH_eval.json, and the speed slice must track
    its three families.
    """
    with open(REPO / "BENCH_eval.json") as f:
        assert check_eval_full_matrix(json.load(f)) == []
    with open(REPO / "BENCH_speed.json") as f:
        assert check_speed_full_matrix(json.load(f)) == []
    with open(REPO / "BENCH_serve.json") as f:
        assert check_serve_slice(json.load(f)) == []


def test_schema_coverage_pins_track_the_live_registries():
    """The jax-free literal pins in bench.schema must mirror the registries.

    schema.py cannot import them (the lint job file-loads it without jax),
    so this tier-1 test is what makes the ``--full`` tripwire actually
    trip: registering a new system/env without growing the pins (and the
    committed artifacts) fails here.
    """
    from repro.bench.schema import (
        FULL_MATRIX_ENVS,
        FULL_MATRIX_SYSTEMS,
        SPEED_SLICE_SYSTEMS,
    )
    from repro.envs import REGISTRY as ENV_REGISTRY
    from repro.systems.registry import REGISTRY as SYS_REGISTRY

    from repro.bench.schema import SERVE_SLICE_SYSTEMS

    assert list(FULL_MATRIX_SYSTEMS) == sorted(SYS_REGISTRY)
    assert list(FULL_MATRIX_ENVS) == sorted(ENV_REGISTRY)
    assert set(SPEED_SLICE_SYSTEMS) <= set(SYS_REGISTRY)
    assert set(SERVE_SLICE_SYSTEMS) <= set(SYS_REGISTRY)
    # the serve slice must keep covering one ff and one recurrent system
    assert any(s.startswith("rec_") for s in SERVE_SLICE_SYSTEMS)
    assert any(not s.startswith("rec_") for s in SERVE_SLICE_SYSTEMS)


def test_full_matrix_pin_catches_missing_recurrent_rows():
    """Dropping a registered system from the artifact fails the full check."""
    with open(REPO / "BENCH_eval.json") as f:
        doc = json.load(f)
    del doc["systems"]["rec_ippo"]
    errs = check_eval_full_matrix(doc)
    assert any("rec_ippo" in e for e in errs)
    with open(REPO / "BENCH_speed.json") as f:
        speed = json.load(f)
    speed["cells"] = [c for c in speed["cells"] if c["system"] != "rec_ippo"]
    errs = check_speed_full_matrix(speed)
    assert any("rec_ippo" in e for e in errs)


def test_speed_schema_catches_drift():
    with open(REPO / "BENCH_speed.json") as f:
        doc = json.load(f)
    assert check_speed_schema(doc) == []
    cell = next(c for c in doc["cells"] if c["compatible"])
    del cell["runners"]["anakin"]["steps_per_sec"]
    doc["config"].pop("num_seeds")
    errs = check_speed_schema(doc)
    assert any("anakin" in e for e in errs)
    assert any("num_seeds" in e for e in errs)
    assert to_markdown  # markdown renderer stays importable with the schema


def test_serve_schema_catches_drift():
    with open(REPO / "BENCH_serve.json") as f:
        doc = json.load(f)
    assert check_serve_schema(doc) == []
    doc["cells"][0]["latency"]["p99_ms"] = 0.5 * doc["cells"][0]["latency"]["p50_ms"]
    del doc["cells"][1]["decisions_per_sec"]
    doc["config"].pop("arrival_rate")
    errs = check_serve_schema(doc)
    assert any("p99" in e for e in errs)
    assert any("decisions_per_sec" in e for e in errs)
    assert any("arrival_rate" in e for e in errs)


def test_serve_slice_catches_missing_slot_counts():
    """One slot count per served system is not a sweep — the pin trips."""
    with open(REPO / "BENCH_serve.json") as f:
        doc = json.load(f)
    slots = sorted({c["max_slots"] for c in doc["cells"]})
    doc["cells"] = [c for c in doc["cells"] if c["max_slots"] == slots[0]]
    errs = check_serve_slice(doc)
    assert any("slot" in e for e in errs)
    with open(REPO / "BENCH_serve.json") as f:
        doc = json.load(f)
    doc["cells"] = [c for c in doc["cells"] if c["system"] != "rec_ippo"]
    errs = check_serve_slice(doc)
    assert any("rec_ippo" in e for e in errs)


def test_eval_schema_catches_drift():
    with open(REPO / "BENCH_eval.json") as f:
        doc = json.load(f)
    sys_name = next(iter(doc["systems"]))
    envs = doc["systems"][sys_name]["envs"]
    cell = next(c for c in envs.values() if c.get("compatible"))
    cell["returns"] = cell["returns"][:-1] + [cell["returns"][-1][:-1]]
    del cell["aggregates"]["iqm_ci95"]
    errs = check_eval_schema(doc)
    assert any("returns" in e for e in errs)
    assert any("iqm_ci95" in e for e in errs)


# ------------------------------------------------------- telemetry parity


def _all_leaves(st, metrics):
    return jax.tree_util.tree_leaves((st.train, metrics))


@pytest.mark.parametrize(
    "make", [_vdn, _ippo], ids=["replay", "rollout"]
)
def test_tapped_run_bitwise_matches_untapped(make):
    """The acceptance pin: a tapped fused run streams >= 2 in-flight rows
    AND is bitwise-identical (params + metrics) to the taps-off run."""
    system = make()
    emitted = []

    def tap(iteration, updates, metrics):
        emitted.append(int(np.asarray(iteration)))

    st_off, m_off = train_anakin(system, jax.random.key(7), 64, num_envs=4)
    st_on, m_on = train_anakin(
        system, jax.random.key(7), 64, num_envs=4,
        log_every=16, log_callback=tap,
    )
    assert emitted == [15, 31, 47, 63]  # >= 2 lines, mid-scan
    for a, b in zip(_all_leaves(st_off, m_off), _all_leaves(st_on, m_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tapped_seed_vmap_bitwise_matches_untapped():
    """Taps stay pure observers under the seed-vmap runner too."""
    system = _vdn()
    keys = jnp.stack([jax.random.key(s) for s in (0, 1, 2)])
    tapped = []
    st_off, m_off = train_anakin(system, keys, 40, num_envs=4, num_seeds=3)
    st_on, m_on = train_anakin(
        system, keys, 40, num_envs=4, num_seeds=3,
        log_every=20, log_callback=lambda it, u, m: tapped.append(m),
    )
    assert len(tapped) == 2
    assert np.asarray(tapped[0]["reward"]).shape == (3,)  # lane axis intact
    for a, b in zip(_all_leaves(st_off, m_off), _all_leaves(st_on, m_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tapped_eval_run_bitwise_matches_untapped():
    """The eval-interleaved (blocked-scan) path honours the same invariant."""
    system = _vdn()
    off = train_anakin(
        system, jax.random.key(2), 40, num_envs=4,
        eval_every=20, eval_episodes=4, eval_num_envs=4,
    )
    hits = []
    on = train_anakin(
        system, jax.random.key(2), 40, num_envs=4,
        eval_every=20, eval_episodes=4, eval_num_envs=4,
        log_every=10, log_callback=lambda it, u, m: hits.append(int(np.asarray(it))),
    )
    assert hits == [9, 19, 29, 39]  # global iteration index across blocks
    for a, b in zip(
        jax.tree_util.tree_leaves((off[0].train, off[1], off[2])),
        jax.tree_util.tree_leaves((on[0].train, on[1], on[2])),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bench_schemas_require_provenance():
    """Artifacts without (or with a gutted) provenance block now fail."""
    with open(REPO / "BENCH_speed.json") as f:
        speed = json.load(f)
    with open(REPO / "BENCH_eval.json") as f:
        ev = json.load(f)
    assert {"git_sha", "jax_version", "backend", "device_kind",
            "num_devices", "timestamp"} <= set(speed["provenance"])
    speed.pop("provenance")
    assert any("provenance" in e for e in check_speed_schema(speed))
    ev["provenance"]["jax_version"] = ""
    assert any("jax_version" in e for e in check_eval_schema(ev))
    with open(REPO / "BENCH_serve.json") as f:
        serve = json.load(f)
    serve.pop("provenance")
    assert any("provenance" in e for e in check_serve_schema(serve))
