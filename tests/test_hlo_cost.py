"""Trip-count-aware HLO cost walker: validated against known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import module_cost


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    a = jnp.ones((128, 64))
    b = jnp.ones((64, 32))
    cost = module_cost(compiled_text(lambda a, b: a @ b, a, b))
    assert cost.flops == 2 * 128 * 64 * 32


@pytest.mark.parametrize("n", [1, 4, 16])
def test_scan_trip_count_multiplies(n):
    def f(x):
        def body(c, _):
            return c @ c, None

        return jax.lax.scan(body, x, None, length=n)[0]

    cost = module_cost(compiled_text(f, jnp.ones((64, 64))))
    assert cost.flops == 2 * 64**3 * n


def test_nested_scan_trip_counts_compose():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        return jax.lax.scan(outer, x, None, length=5)[0]

    cost = module_cost(compiled_text(f, jnp.ones((32, 32))))
    assert cost.flops == 2 * 32**3 * 15


def test_bytes_nonzero_and_scale_with_trip_count():
    def f(x, n):
        def body(c, _):
            return jnp.sin(c) + 1.0, None

        return jax.lax.scan(body, x, None, length=n)[0]

    c1 = module_cost(compiled_text(lambda x: f(x, 2), jnp.ones((1024,))))
    c2 = module_cost(compiled_text(lambda x: f(x, 20), jnp.ones((1024,))))
    assert c2.bytes > 5 * c1.bytes


def test_batched_dot_counts_batch_dims():
    a = jnp.ones((8, 32, 16))
    b = jnp.ones((8, 16, 24))
    cost = module_cost(
        compiled_text(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    )
    assert cost.flops == 2 * 8 * 32 * 16 * 24
