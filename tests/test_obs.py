"""repro.obs: sinks, streaming tap, run records, profiler hooks."""
import csv
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench.schema import check_provenance, check_run_record
from repro.core.system import train_anakin
from repro.envs import MatrixGame
from repro.obs import (
    ConsoleSink,
    CsvSink,
    JsonlSink,
    MetricTap,
    MultiLogger,
    RetraceCounter,
    RunRecord,
    SeedAggregator,
    measure_phase_timing,
    profile_trace,
    provenance,
    roofline_summary,
)
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.vdn import make_vdn

CFG = OffPolicyConfig(buffer_capacity=500, min_replay=50, batch_size=16)


def _vdn():
    return make_vdn(MatrixGame(horizon=10), CFG)


class CaptureSink:
    """A test double recording every (metrics, step) write."""

    def __init__(self):
        self.rows = []
        self.closed = False

    def write(self, metrics, step=None):
        self.rows.append((dict(metrics), step))

    def close(self):
        self.closed = True


# ------------------------------------------------------------------- sinks


def test_jsonl_sink_round_trips(tmp_path):
    path = tmp_path / "metrics.jsonl"
    sink = JsonlSink(path)
    rows = [
        {"reward": 1.5, "sps": 1000.0, "updates": 3},
        {"reward": np.float32(-2.25), "sps": jnp.asarray(2000.0), "updates": 4},
    ]
    for i, row in enumerate(rows):
        sink.write(row, step=i)
    sink.close()
    back = [json.loads(line) for line in path.read_text().splitlines()]
    assert back == [
        {"step": 0, "reward": 1.5, "sps": 1000.0, "updates": 3},
        {"step": 1, "reward": -2.25, "sps": 2000.0, "updates": 4},
    ]


def test_csv_sink_round_trips(tmp_path):
    path = tmp_path / "metrics.csv"
    sink = CsvSink(path)
    sink.write({"reward": 1.5, "updates": 3}, step=10)
    sink.write({"reward": -0.5, "updates": 4}, step=20)
    sink.close()
    with open(path) as f:
        back = list(csv.DictReader(f))
    assert [r["step"] for r in back] == ["10", "20"]
    assert [float(r["reward"]) for r in back] == [1.5, -0.5]
    assert [int(r["updates"]) for r in back] == [3, 4]


def test_csv_sink_rejects_schema_drift(tmp_path):
    sink = CsvSink(tmp_path / "m.csv")
    sink.write({"a": 1.0}, step=0)
    sink.write({}, step=1)  # missing columns are fine (logged empty)
    with pytest.raises(ValueError, match="not in the header"):
        sink.write({"a": 1.0, "surprise": 2.0}, step=2)
    sink.close()


def test_console_sink_single_formatting_path(capsys):
    console = ConsoleSink()
    console.write({"reward": 1.23456, "updates": 7}, step=5)
    console.line("free-form report")
    out = capsys.readouterr().out.splitlines()
    assert out[0] == "step=5  reward=1.235  updates=7"
    assert out[1] == "free-form report"


def test_multi_logger_fans_out_and_closes():
    a, b = CaptureSink(), CaptureSink()
    logger = MultiLogger(a, b)
    logger.write({"x": 1}, step=0)
    logger.close()
    assert a.rows == b.rows == [({"x": 1}, 0)]
    assert a.closed and b.closed


def test_seed_aggregator_reduces_lane_axes():
    inner = CaptureSink()
    logger = SeedAggregator(inner)
    logger.write(
        {"reward": np.array([1.0, 3.0, 5.0]), "iteration": 7, "tag": "x"},
        step=7,
    )
    (row, step), = inner.rows
    assert step == 7
    assert row["reward"] == pytest.approx(3.0)       # mean over lanes
    assert row["reward/min"] == pytest.approx(1.0)
    assert row["reward/max"] == pytest.approx(5.0)
    assert row["iteration"] == 7 and row["tag"] == "x"  # scalars untouched


def test_seed_aggregator_means_trailing_dims_within_lane():
    inner = CaptureSink()
    SeedAggregator(inner).write({"m": np.arange(6.0).reshape(2, 3)})
    (row, _), = inner.rows
    assert row["m"] == pytest.approx(2.5)
    assert row["m/min"] == pytest.approx(1.0)  # lane 0 mean
    assert row["m/max"] == pytest.approx(4.0)  # lane 1 mean


# ----------------------------------------------------------- streaming tap


def test_metric_tap_counts_and_reports_sps():
    sink = CaptureSink()
    tap = MetricTap(sink, log_every=8, steps_per_iteration=4)
    tap(7, 2, {"reward": 0.5})
    tap(np.int32(15), 4, {"reward": 1.5})
    assert tap.emits == 2
    (r0, s0), (r1, s1) = sink.rows
    assert (s0, s1) == (8, 16)
    assert r0["iteration"] == 8 and r1["iteration"] == 16
    assert r0["sps"] > 0 and r1["sps"] > 0
    assert r1["updates"] == 4 and r1["reward"] == 1.5


def test_metric_tap_rejects_nonpositive_period():
    with pytest.raises(ValueError, match="log_every"):
        MetricTap(CaptureSink(), log_every=0, steps_per_iteration=1)


def test_train_anakin_streams_inflight_metrics():
    """A fused run with log_every set emits rows *during* the scan."""
    sink = CaptureSink()
    tap = MetricTap(sink, log_every=16, steps_per_iteration=4)
    train_anakin(
        _vdn(), jax.random.key(0), 64, num_envs=4,
        log_every=16, log_callback=tap,
    )
    assert tap.emits == 4  # >= 2 in-flight lines is the acceptance bar
    steps = [s for _, s in sink.rows]
    assert steps == [16, 32, 48, 64]
    for row, _ in sink.rows:
        assert {"iteration", "updates", "sps", "reward"} <= set(row)


def test_train_anakin_tap_covers_seed_vmap_lanes():
    sink = CaptureSink()
    tap = MetricTap(SeedAggregator(sink), log_every=10, steps_per_iteration=8)
    keys = jnp.stack([jax.random.key(s) for s in (0, 1)])
    train_anakin(
        _vdn(), keys, 20, num_envs=4, num_seeds=2,
        log_every=10, log_callback=tap,
    )
    assert tap.emits == 2
    for row, _ in sink.rows:
        assert "reward/min" in row and "reward/max" in row


# ------------------------------------------------------------- run records


def test_provenance_block_conforms():
    assert check_provenance({"provenance": provenance()}) == []


def test_run_record_schema_round_trip(tmp_path):
    record = RunRecord(tmp_path, config={"system": "vdn"}, tag="vdn-test")
    record.update(
        "timing", total_seconds=1.5, compile_seconds=1.0, steady_seconds=0.5
    )
    record.update("timing", phases={"rollout_seconds": 0.1})
    record.update("retrace", jaxpr_traces=3, backend_compiles=1,
                  compile_seconds=1.0)
    record.update("metrics", reward_last10pct=0.25)
    path = record.save()
    with open(path) as f:
        doc = json.load(f)
    assert check_run_record(doc) == []
    assert doc["config"] == {"system": "vdn"}
    assert doc["run_id"].startswith("vdn-test-")
    assert record.metrics_path("jsonl").parent == record.dir


def test_run_record_schema_catches_drift(tmp_path):
    record = RunRecord(tmp_path, tag="t")
    record.update(
        "timing", total_seconds=1.0, compile_seconds=0.5, steady_seconds=0.5
    )
    with open(record.save()) as f:
        doc = json.load(f)
    doc["timing"].pop("compile_seconds")
    doc["provenance"].pop("git_sha")
    doc["profile"] = {"trace_dir": 3}
    errs = check_run_record(doc)
    assert any("compile_seconds" in e for e in errs)
    assert any("git_sha" in e for e in errs)
    assert any("trace_dir" in e for e in errs)
    assert check_run_record({"run_id": ""})  # everything missing


def test_check_bench_schema_script_validates_run_records(tmp_path):
    """scripts/check_bench_schema.py dispatches run.json by its run_id key."""
    import importlib.util
    import pathlib

    script = (
        pathlib.Path(__file__).resolve().parent.parent
        / "scripts" / "check_bench_schema.py"
    )
    spec = importlib.util.spec_from_file_location("cbs", script)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    record = RunRecord(tmp_path, tag="ok")
    record.update(
        "timing", total_seconds=1.0, compile_seconds=0.5, steady_seconds=0.5
    )
    path = record.save()
    assert mod.main([str(path)]) == 0
    record.doc["timing"].pop("total_seconds")
    record.save()
    assert mod.main([str(path)]) == 1


# ---------------------------------------------------------- profiler hooks


def test_retrace_counter_sees_fresh_compiles():
    with RetraceCounter() as rc:
        jax.jit(lambda x: x * 2.0 + 1.0)(jnp.ones((3,)))
    assert rc.jaxpr_traces >= 1
    assert rc.backend_compiles >= 1
    assert rc.compile_seconds > 0
    summary = rc.summary()
    assert set(summary) == {"jaxpr_traces", "backend_compiles", "compile_seconds"}
    # cached second call: no new compiles inside a fresh region
    fn = jax.jit(lambda x: x - 1.0)
    fn(jnp.ones((2,)))
    with RetraceCounter() as rc2:
        fn(jnp.ones((2,)))
    assert rc2.backend_compiles == 0


def test_profile_trace_writes_directory(tmp_path):
    with profile_trace(tmp_path / "trace") as info:
        jax.block_until_ready(jnp.ones((8, 8)) @ jnp.ones((8, 8)))
    assert (tmp_path / "trace").is_dir()
    assert info["trace_dir"] == str(tmp_path / "trace")


def test_roofline_summary_counts_scanned_flops():
    def body(c, _):
        return c @ jnp.ones((8, 8)), None

    def fn(x):
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    text = jax.jit(fn).lower(jnp.ones((8, 8))).compile().as_text()
    summary = roofline_summary(text)
    # 10 trips x (2 * 8^3) flops — trip-count awareness is the point
    assert summary["hlo_flops"] == pytest.approx(10 * 2 * 8**3)
    assert summary["hlo_bytes"] > 0


def test_measure_phase_timing_smoke():
    phases = measure_phase_timing(
        _vdn(), num_envs=2, key=jax.random.key(0), eval_episodes=2,
        repeats=1,
    )
    assert set(phases) == {"rollout_seconds", "update_seconds", "eval_seconds"}
    assert all(v > 0 for v in phases.values())
