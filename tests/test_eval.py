"""repro.eval: evaluator determinism, robust stats, fused eval cadence."""
import jax
import numpy as np
import pytest

from repro.core.system import train_anakin
from repro.core.types import EvalMetrics
from repro.envs import MatrixGame, SmaxLite, make_env
from repro.eval import (
    aggregate,
    evaluate,
    iqm,
    make_evaluator,
    mean,
    median,
    stratified_bootstrap_ci,
)
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.vdn import make_vdn

CFG = OffPolicyConfig(buffer_capacity=2_000, min_replay=50, batch_size=16)


def _vdn(env):
    return make_vdn(env, CFG)


# ------------------------------------------------------------- evaluator


def test_evaluator_deterministic_bitwise():
    """Same (params, key) -> bitwise-equal returns across calls."""
    system = _vdn(MatrixGame(horizon=10))
    train = system.init_train(jax.random.key(1))
    key = jax.random.key(7)
    m1 = evaluate(system, train, key, num_episodes=12, num_envs=4)
    m2 = evaluate(system, train, key, num_episodes=12, num_envs=4)
    assert m1.episode_return.shape == (12,)
    np.testing.assert_array_equal(
        np.asarray(m1.episode_return), np.asarray(m2.episode_return)
    )
    for a in m1.agent_returns:
        np.testing.assert_array_equal(
            np.asarray(m1.agent_returns[a]), np.asarray(m2.agent_returns[a])
        )


def test_evaluator_accepts_bare_params_and_trims_episodes():
    system = _vdn(MatrixGame(horizon=10))
    train = system.init_train(jax.random.key(1))
    key = jax.random.key(0)
    m_train = evaluate(system, train, key, num_episodes=7, num_envs=4)
    m_params = evaluate(system, train.params, key, num_episodes=7, num_envs=4)
    # 7 episodes from 4 envs = 2 rounds trimmed to 7
    assert m_params.episode_return.shape == (7,)
    np.testing.assert_array_equal(
        np.asarray(m_train.episode_return), np.asarray(m_params.episode_return)
    )


def test_evaluator_masks_early_termination():
    """smax-lite episodes can end before the horizon; rewards stop counting."""
    system = _vdn(SmaxLite(num_agents=3))
    train = system.init_train(jax.random.key(3))
    m = evaluate(system, train, jax.random.key(0), num_episodes=6, num_envs=3)
    lengths = np.asarray(m.episode_length)
    assert (lengths >= 1).all() and (lengths <= system.env.horizon).all()
    assert np.isfinite(np.asarray(m.episode_return)).all()


def test_make_env_registry_roundtrip():
    env = make_env("matrix_game", horizon=5)
    assert env.horizon == 5
    with pytest.raises(KeyError):
        make_env("not_an_env")


# ----------------------------------------------------------------- stats


def test_iqm_hand_computed():
    # 1..8: drop the two lowest and two highest -> mean(3,4,5,6) = 4.5
    assert iqm([1, 2, 3, 4, 5, 6, 7, 8]) == pytest.approx(4.5)
    # outlier-robust where the mean is not
    assert iqm([1, 2, 3, 4, 5, 6, 7, 1000]) == pytest.approx(4.5)
    assert mean([1, 2, 3, 4, 5, 6, 7, 1000]) == pytest.approx(128.5)
    # fewer than 4 scores falls back to the plain mean
    assert iqm([2.0, 4.0]) == pytest.approx(3.0)
    assert median([[1, 2], [3, 4]]) == pytest.approx(2.5)


def test_bootstrap_ci_constant_and_ordering():
    # constant scores -> degenerate CI exactly at the value
    lo, hi = stratified_bootstrap_ci(np.full((3, 8), 5.0), num_resamples=100)
    assert lo == pytest.approx(5.0) and hi == pytest.approx(5.0)
    # varied scores -> non-degenerate interval that brackets the statistic
    rng = np.random.default_rng(0)
    scores = rng.normal(0.0, 1.0, size=(4, 64))
    lo, hi = stratified_bootstrap_ci(scores, num_resamples=500, seed=1)
    assert lo < iqm(scores) < hi
    # deterministic for a fixed bootstrap seed
    assert (lo, hi) == stratified_bootstrap_ci(scores, num_resamples=500, seed=1)


def test_aggregate_report_schema():
    rep = aggregate(np.arange(16, dtype=float).reshape(2, 8), num_resamples=50)
    for k in ("mean", "median", "iqm", "std", "iqm_ci95", "mean_ci95"):
        assert k in rep
    assert rep["num_seeds"] == 2 and rep["num_episodes"] == 8
    lo, hi = rep["iqm_ci95"]
    assert lo <= rep["iqm"] <= hi


# ------------------------------------------------- fused eval in the runners


def test_train_anakin_eval_cadence_smoke():
    """--eval-every through the fused jit: right shapes, finite values."""
    system = _vdn(MatrixGame(horizon=10))
    st, metrics, evals = train_anakin(
        system, jax.random.key(0), 60, num_envs=4,
        eval_every=20, eval_episodes=8, eval_num_envs=4,
    )
    assert isinstance(evals, EvalMetrics)
    assert evals.episode_return.shape == (3, 8)  # 3 eval points x 8 episodes
    assert metrics["reward"].shape == (60,)  # training metrics still flat
    assert np.isfinite(np.asarray(evals.episode_return)).all()
    assert set(evals.agent_returns) == set(system.spec.agent_ids)


def test_train_anakin_interleaved_matches_standalone():
    """The in-jit evaluator reproduces the standalone one bit-for-bit."""
    system = _vdn(MatrixGame(horizon=10))
    n = 40
    _, _, evals = train_anakin(
        system, jax.random.key(0), n, num_envs=4,
        eval_every=n, eval_episodes=8, eval_num_envs=4,
    )
    # re-run training without eval to recover the same train state + key
    st, _ = train_anakin(system, jax.random.key(0), n, num_envs=4)
    k_eval = jax.random.split(st.key)[0]
    standalone = evaluate(system, st.train, k_eval, num_episodes=8, num_envs=4)
    np.testing.assert_allclose(
        np.asarray(evals.episode_return)[0],
        np.asarray(standalone.episode_return),
        rtol=1e-6,
    )


def test_train_anakin_eval_every_must_divide():
    system = _vdn(MatrixGame(horizon=10))
    with pytest.raises(ValueError):
        train_anakin(system, jax.random.key(0), 50, 4, eval_every=7)


def test_make_evaluator_composes_under_jit():
    """The eval fn is a pure function usable inside a larger jit."""
    system = _vdn(MatrixGame(horizon=10))
    eval_fn = make_evaluator(system, num_episodes=4, num_envs=4)
    train = system.init_train(jax.random.key(1))

    @jax.jit
    def wrapped(train, key):
        return eval_fn(train, key).episode_return.mean()

    out = wrapped(train, jax.random.key(0))
    assert np.isfinite(float(out))
