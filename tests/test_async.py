"""Async actor/learner runner: queue semantics, bitwise staleness-0 parity
with anakin, bounded staleness, V-trace correctness (see docs/DISTRIBUTED.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.buffer import (
    queue_capacity,
    queue_init,
    queue_pop,
    queue_push,
    queue_size,
)
from repro.core.system import make_anakin
from repro.distributed.impala import default_unroll_len, make_async, train_async
from repro.envs import make_env
from repro.systems.registry import make_system
from repro.systems.vtrace import vtrace_advantages

PPO_SMOKE = dict(
    hidden_sizes=(32, 32), rollout_len=8, epochs=1, num_minibatches=2
)


def leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all((x == y).all() for x, y in zip(la, lb))


# ------------------------------------------------------- trajectory queue


def test_queue_fifo_order():
    q = queue_init({"x": jnp.zeros(())}, capacity=3)
    for v in (1.0, 2.0, 3.0):
        q, ok = queue_push(q, {"x": jnp.asarray(v)})
        assert bool(ok)
    assert int(queue_capacity(q)) == 3 and int(queue_size(q)) == 3
    out = []
    for _ in range(3):
        q, item = queue_pop(q)
        out.append(float(item["x"]))
    assert out == [1.0, 2.0, 3.0] and int(queue_size(q)) == 0


def test_queue_overflow_drops_incoming():
    q = queue_init({"x": jnp.zeros(())}, capacity=2)
    for v in (1.0, 2.0):
        q, ok = queue_push(q, {"x": jnp.asarray(v)})
    q, ok = queue_push(q, {"x": jnp.asarray(99.0)})
    assert not bool(ok) and int(queue_size(q)) == 2
    q, item = queue_pop(q)
    assert float(item["x"]) == 1.0  # queued items untouched by the drop


def test_queue_pop_empty_leaves_queue_empty():
    q = queue_init({"x": jnp.zeros(())}, capacity=2)
    q, _ = queue_pop(q)
    assert int(queue_size(q)) == 0 and int(q.head) == 0


def test_queue_wraps_around():
    q = queue_init({"x": jnp.zeros(())}, capacity=2)
    q, _ = queue_push(q, {"x": jnp.asarray(1.0)})
    q, _ = queue_push(q, {"x": jnp.asarray(2.0)})
    q, item = queue_pop(q)
    q, _ = queue_push(q, {"x": jnp.asarray(3.0)})  # reuses slot 0
    q, item = queue_pop(q)
    assert float(item["x"]) == 2.0
    q, item = queue_pop(q)
    assert float(item["x"]) == 3.0


# --------------------------------------------- staleness-0 bitwise parity


def test_async_staleness_zero_bitwise_matches_anakin_ff():
    """1 actor, sync every tick, unroll == rollout: anakin's exact program."""
    env = make_env("matrix_game")
    system = make_system("ippo", env, **PPO_SMOKE)
    key = jax.random.key(0)
    st_a, m_a = make_anakin(system, 32, 4)(key)
    st_b, m_b = make_async(system, 32, 4, 1, param_sync_every=1)(key)
    assert leaves_equal(st_a.train.params, st_b.train.params)
    assert leaves_equal(st_a.train.opt_state, st_b.train.opt_state)
    assert int(st_a.train.steps) == int(st_b.train.steps) > 0
    # the acting stream is identical too, not just the updates: the async
    # tick metric is the mean over its unroll (and actor lane), so anakin's
    # per-iteration stream averaged per tick must reproduce it
    np.testing.assert_allclose(
        np.asarray(m_a["reward"]).reshape(4, 8).mean(axis=1),
        np.asarray(m_b["reward"]),
        rtol=1e-6,
    )
    assert float(m_b["dropped"][-1]) == 0.0
    assert float(np.max(np.asarray(m_b["staleness"]))) == 0.0


def test_async_staleness_zero_bitwise_matches_anakin_replay():
    """Replay regime at unroll 1 keeps anakin's per-step update cadence."""
    env = make_env("matrix_game")
    system = make_system(
        "vdn", env, hidden_sizes=(32, 32), batch_size=32,
        buffer_capacity=5_000, min_replay=64,
    )
    key = jax.random.key(1)
    st_a, _ = make_anakin(system, 64, 4)(key)
    st_b, _ = make_async(system, 64, 4, 1, unroll_len=1)(key)
    assert leaves_equal(st_a.train.params, st_b.train.params)
    assert int(st_a.train.steps) == int(st_b.train.steps) > 0


def test_async_staleness_zero_bitwise_matches_anakin_recurrent():
    env = make_env("matrix_game")
    system = make_system("rec_ippo", env, **PPO_SMOKE)
    key = jax.random.key(2)
    st_a, _ = make_anakin(system, 16, 4)(key)
    st_b, _ = make_async(system, 16, 4, 1, param_sync_every=1)(key)
    assert leaves_equal(st_a.train.params, st_b.train.params)
    assert int(st_a.train.steps) == int(st_b.train.steps) > 0


# ----------------------------------------------- staleness bound + scaling


def test_param_sync_every_bounds_staleness():
    env = make_env("matrix_game")
    system = make_system("ippo", env, **PPO_SMOKE)
    _, m = make_async(system, 64, 4, 1, param_sync_every=4)(jax.random.key(0))
    staleness = np.asarray(m["staleness"])
    assert staleness.max() <= 4 - 1  # consumed chunk is at most sync-1 behind
    assert staleness.max() > 0  # and the runner really does run stale
    # sync ticks start each cycle back at staleness 0
    assert staleness[0] == 0.0 and staleness[4] == 0.0


def test_multi_actor_training_runs_and_scales_steps():
    env = make_env("matrix_game")
    system = make_system("ippo", env, **PPO_SMOKE)
    st1, _ = make_async(system, 16, 4, 1)(jax.random.key(0))
    st4, m4 = make_async(system, 16, 4, 4)(jax.random.key(0))
    # 4 actors deliver 4x the chunks -> 4x the updates for the same ticks
    assert int(st4.train.steps) == 4 * int(st1.train.steps) > 0
    assert all(
        bool(jnp.isfinite(x).all())
        for x in jax.tree_util.tree_leaves(st4.train.params)
    )
    assert float(m4["dropped"][-1]) == 0.0


def test_train_async_wrapper_and_program_handles():
    env = make_env("matrix_game")
    system = make_system("ippo", env, **PPO_SMOKE)
    program = make_async(system, 16, 4, 2)
    assert program.unroll_len == 8 and program.num_ticks == 2
    assert hasattr(program, "fused") and hasattr(program, "init_fn")
    st, m = train_async(system, jax.random.key(3), 16, 4, 2)
    assert int(st.tick) == 2
    assert m["queue_depth"].shape == (2,)


def test_default_unroll_len_per_regime():
    env = make_env("matrix_game")
    assert default_unroll_len(make_system("ippo", env, **PPO_SMOKE)) == 8
    assert default_unroll_len(make_system("vdn", env)) == 8  # replay default


def test_async_rejects_bad_schedule():
    env = make_env("matrix_game")
    system = make_system("ippo", env, **PPO_SMOKE)
    with pytest.raises(ValueError, match="multiple of the"):
        make_async(system, 30, 4, 1)
    with pytest.raises(ValueError, match="num_actors"):
        make_async(system, 16, 4, 0)
    with pytest.raises(ValueError, match="param_sync_every"):
        make_async(system, 16, 4, 1, param_sync_every=0)


# ------------------------------------------------------------------ V-trace


def test_vtrace_equals_gae_on_policy_at_lam_one():
    """rho = c = 1 and lam = 1: V-trace is exactly this repo's GAE."""
    key = jax.random.key(0)
    T, B = 12, 5
    ks = jax.random.split(key, 5)
    v = jax.random.normal(ks[0], (T, B))
    last_v = jax.random.normal(ks[1], (B,))
    r = jax.random.normal(ks[2], (T, B))
    disc = 0.99 * jax.random.bernoulli(ks[3], 0.9, (T, B)).astype(jnp.float32)
    logp = jax.random.normal(ks[4], (T, B))  # behaviour == current

    adv_vt, ret_vt = vtrace_advantages(logp, logp, v, last_v, r, disc, lam=1.0)

    def back(carry, inp):
        g, v_next = carry
        v_t, r_t, d_t = inp
        delta = r_t + d_t * v_next - v_t
        g = delta + d_t * 1.0 * g
        return (g, v_t), g

    (_, _), adv_gae = jax.lax.scan(
        back, (jnp.zeros_like(last_v), last_v), (v, r, disc), reverse=True
    )
    np.testing.assert_allclose(adv_vt, adv_gae, atol=1e-5)
    np.testing.assert_allclose(ret_vt, adv_gae + v, atol=1e-5)


def test_vtrace_truncates_importance_ratios():
    """A hugely off-policy step's correction is capped at clip_rho."""
    T, B = 4, 1
    v = jnp.zeros((T, B))
    last_v = jnp.zeros((B,))
    r = jnp.ones((T, B))
    disc = jnp.zeros((T, B))  # isolate the per-step delta: adv = rho * r
    curr = jnp.full((T, B), 5.0)
    behaviour = jnp.zeros((T, B))  # ratio e^5 >> clip
    adv, _ = vtrace_advantages(
        curr, behaviour, v, last_v, r, disc, clip_rho=1.0
    )
    np.testing.assert_allclose(adv, jnp.ones((T, B)), atol=1e-6)


def test_vtrace_system_trains_under_staleness():
    env = make_env("matrix_game")
    system = make_system("ippo", env, use_vtrace=True, **PPO_SMOKE)
    st, _ = make_async(system, 32, 4, 2, param_sync_every=2)(jax.random.key(0))
    assert int(st.train.steps) > 0
    assert all(
        bool(jnp.isfinite(x).all())
        for x in jax.tree_util.tree_leaves(st.train.params)
    )
