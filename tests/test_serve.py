"""The decision-serving engine: admission order, carry hygiene, parity.

The load-bearing pin is greedy parity: decisions served out of the slot
pool must be bitwise what `repro.eval`'s fused evaluator computes for the
same episodes — same reset keys in, same actions and returns out,
regardless of pool size.  That is what makes BENCH_serve a measurement of
the *trained policy*, not of a serving-only code path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench.throughput import smoke_overrides
from repro.core.system import train_anakin
from repro.eval import evaluate
from repro.serve import (
    DecisionEngine,
    ServeRequest,
    load_policy,
    poisson_requests,
    read_policy_meta,
    save_policy,
    serve_workload,
    workload_stats,
)
from repro.systems.registry import make_pair

HORIZON = 10  # matrix_game episode length


def _tiny(name):
    """A registry (env, system) pair at smoke-test size."""
    return make_pair(name, "matrix_game", **smoke_overrides(name))


def _eval_reset_keys(key, num_envs):
    """The env-reset keys `evaluate(system, train, key, B, B)` uses.

    Mirrors the evaluator's split chain (one_round then _episode_batch),
    so requests carrying these keys serve the *same episodes* eval rolls.
    """
    _, kr = jax.random.split(key)
    k_reset, _ = jax.random.split(kr)
    return jax.random.split(k_reset, num_envs)


# ------------------------------------------------------------- admission


def test_admission_and_recycle_order_is_deterministic():
    _, system = _tiny("vdn")
    train = system.init_train(jax.random.key(0))
    engine = DecisionEngine(system, train, max_slots=2, warmup=False)
    for i in range(5):
        engine.submit(ServeRequest(uid=i, key=jax.random.key(100 + i)))

    finished = engine.run_until_drained()
    # FIFO queue x lowest-free-slot-first: 0,1 start; 2,3 recycle those
    # slots in order; 4 takes the first slot to free again
    assert [r.uid for r in finished] == [0, 1, 2, 3, 4]
    assert [r.slot for r in finished] == [0, 1, 0, 1, 0]
    assert all(r.done and r.length == HORIZON for r in finished)
    assert engine.idle() and engine.num_live == 0


def test_queue_overflow_waits_for_free_slots():
    _, system = _tiny("vdn")
    train = system.init_train(jax.random.key(0))
    engine = DecisionEngine(system, train, max_slots=1, warmup=False)
    for i in range(3):
        engine.submit(ServeRequest(uid=i, key=jax.random.key(i)))
    engine.tick()
    assert engine.num_live == 1 and len(engine.queue) == 2
    finished = engine.run_until_drained()
    assert [r.uid for r in finished] == [0, 1, 2]


def test_engine_rejects_bad_config():
    _, system = _tiny("vdn")
    train = system.init_train(jax.random.key(0))
    with pytest.raises(ValueError):
        DecisionEngine(system, train, max_slots=0, warmup=False)
    with pytest.raises(ValueError):
        DecisionEngine(system, train, mode="argmax", warmup=False)


# ---------------------------------------------------------- carry hygiene


def _hidden_rows(engine):
    """Stack every hidden leaf to (leaves, max_slots, H): rows by slot."""
    leaves = jax.tree_util.tree_leaves(engine.carry.hidden)
    return np.stack([np.asarray(x) for x in leaves])


def test_recurrent_carry_zeroed_on_admission_and_at_boundary():
    _, system = _tiny("rec_ippo")
    train = system.init_train(jax.random.key(0))
    engine = DecisionEngine(system, train, max_slots=2, warmup=False)

    engine.submit(ServeRequest(uid=0, key=jax.random.key(1)))
    for _ in range(3):
        engine.tick()
    hidden = _hidden_rows(engine)
    # every pool row was stepped (free slots burn FLOPs), so both rows
    # hold non-zero GRU state by now
    assert np.abs(hidden[:, 0]).sum() > 0.0
    assert np.abs(hidden[:, 1]).sum() > 0.0

    # admission must zero exactly the admitted slot's memory (slot 1),
    # leaving the live episode's state (slot 0) untouched
    engine.submit(ServeRequest(uid=1, key=jax.random.key(2)))
    engine._admit()
    after = _hidden_rows(engine)
    np.testing.assert_array_equal(after[:, 1], np.zeros_like(after[:, 1]))
    np.testing.assert_array_equal(after[:, 0], hidden[:, 0])

    # at the episode boundary (LAST) the retiring slot's carry is zeroed
    # inside the same tick, so a recycled slot can never leak user state
    for _ in range(HORIZON - 3):
        engine.tick()
    assert engine.slots[0] is None  # uid 0 retired
    boundary = _hidden_rows(engine)
    np.testing.assert_array_equal(
        boundary[:, 0], np.zeros_like(boundary[:, 0])
    )
    assert np.abs(boundary[:, 1]).sum() > 0.0  # uid 1 still running


# ---------------------------------------------------------- greedy parity


@pytest.mark.parametrize("name", ["ippo", "rec_ippo"])
def test_served_greedy_episodes_bitwise_match_eval(name):
    """Served returns == `repro.eval.evaluate` returns, bit for bit."""
    _, system = _tiny(name)
    train = system.init_train(jax.random.key(3))
    key = jax.random.key(7)
    B = 4

    ev = evaluate(system, train, key, num_episodes=B, num_envs=B)
    reset_keys = _eval_reset_keys(key, B)

    for max_slots in (B, 2):
        engine = DecisionEngine(
            system, train, max_slots=max_slots, warmup=False
        )
        for i in range(B):
            engine.submit(ServeRequest(uid=i, key=reset_keys[i]))
        finished = sorted(engine.run_until_drained(), key=lambda r: r.uid)
        served = np.asarray([r.episode_return for r in finished], np.float32)
        np.testing.assert_array_equal(served, np.asarray(ev.episode_return))
        for a in system.spec.agent_ids:
            np.testing.assert_array_equal(
                np.asarray([r.agent_returns[a] for r in finished], np.float32),
                np.asarray(ev.agent_returns[a]),
            )
        np.testing.assert_array_equal(
            np.asarray([r.length for r in finished]),
            np.asarray(ev.episode_length),
        )


@pytest.mark.parametrize("name", ["ippo", "rec_ippo"])
def test_served_greedy_actions_bitwise_match_reference(name):
    """Per-step served actions == an unrolled greedy reference loop."""
    _, system = _tiny(name)
    env = system.env
    train = system.init_train(jax.random.key(3))
    B = 3
    reset_keys = jax.random.split(jax.random.key(11), B)
    ids = list(system.spec.agent_ids)

    # reference: the evaluator's episode roll, unrolled in python
    env_state, ts = jax.vmap(env.reset)(reset_keys)
    carry = system.initial_carry((B,))
    reference = []
    for t in range(HORIZON):
        gs = jax.vmap(env.global_state)(env_state)
        actions, carry, _ = system.select_actions(
            train, ts.observation, gs, carry, jax.random.key(t),
            training=False,
        )
        env_state, ts = jax.vmap(env.step)(env_state, actions)
        reference.append({a: np.asarray(actions[a]) for a in ids})

    engine = DecisionEngine(
        system, train, max_slots=B, record_actions=True, warmup=False
    )
    for i in range(B):
        engine.submit(ServeRequest(uid=i, key=reset_keys[i]))
    finished = sorted(engine.run_until_drained(), key=lambda r: r.uid)
    for i, req in enumerate(finished):
        assert len(req.actions) == HORIZON
        for t, decision in enumerate(req.actions):
            for a in ids:
                np.testing.assert_array_equal(
                    decision[a], reference[t][a][i]
                )


def test_sample_mode_actions_differ_from_greedy():
    _, system = _tiny("ippo")
    train = system.init_train(jax.random.key(0))
    streams = {}
    for mode in ("greedy", "sample"):
        engine = DecisionEngine(
            system, train, max_slots=2, mode=mode, record_actions=True,
            warmup=False,
        )
        for i in range(4):
            engine.submit(ServeRequest(uid=i, key=jax.random.key(50 + i)))
        finished = sorted(engine.run_until_drained(), key=lambda r: r.uid)
        streams[mode] = [
            np.asarray([d[a] for d in r.actions])
            for r in finished for a in system.spec.agent_ids
        ]
    same = all(
        np.array_equal(g, s)
        for g, s in zip(streams["greedy"], streams["sample"])
    )
    assert not same, "sampled traffic should not replay the greedy stream"


# ------------------------------------------------------- traffic + stats


def test_poisson_requests_are_reproducible_and_ordered():
    a = poisson_requests(4, 3, 0.5, seed=9)
    b = poisson_requests(4, 3, 0.5, seed=9)
    assert len(a) == 12
    assert [r.arrival_tick for r in a] == [r.arrival_tick for r in b]
    assert all(
        np.array_equal(
            jax.random.key_data(x.key), jax.random.key_data(y.key)
        )
        for x, y in zip(a, b)
    )
    ticks = [r.arrival_tick for r in a]
    assert ticks == sorted(ticks)
    assert [r.uid for r in a] == list(range(12))
    c = poisson_requests(4, 3, 0.5, seed=10)
    assert [r.arrival_tick for r in c] != ticks or not all(
        np.array_equal(
            jax.random.key_data(x.key), jax.random.key_data(y.key)
        )
        for x, y in zip(a, c)
    )


def test_poisson_requests_reject_bad_rate():
    with pytest.raises(ValueError):
        poisson_requests(2, 2, 0.0)


def test_serve_workload_serves_every_request():
    _, system = _tiny("vdn")
    train = system.init_train(jax.random.key(0))
    engine = DecisionEngine(system, train, max_slots=2, warmup=False)
    requests = poisson_requests(3, 2, 0.3, seed=1)
    stats = serve_workload(engine, requests)
    assert stats["episodes"] == len(requests)
    assert stats["decisions"] == len(requests) * HORIZON
    assert stats["decisions_per_sec"] > 0
    assert stats["latency"]["p99_ms"] >= stats["latency"]["p50_ms"] > 0


def test_workload_stats_weights_latency_by_live_slots():
    log = [{"seconds": 0.001, "live": 1}, {"seconds": 0.003, "live": 3}]
    stats = workload_stats(log, [])
    # 4 decisions: one at 1ms, three at 3ms -> p50 is 3ms, mean 2.5ms
    assert stats["decisions"] == 4
    assert stats["latency"]["p50_ms"] == pytest.approx(3.0)
    assert stats["latency"]["mean_ms"] == pytest.approx(2.5)
    with pytest.raises(ValueError):
        workload_stats([], [])


# ----------------------------------------------------- policy round trip


def test_policy_checkpoint_round_trip_serves_identically(tmp_path):
    """save_policy -> load_policy -> served returns match the original."""
    _, system = _tiny("rec_ippo")
    key = jax.random.key(0)
    st, _ = train_anakin(system, key, 8, 4)

    d = str(tmp_path / "pol")
    save_policy(
        d, "rec_ippo", "matrix_game",
        st.train, config_overrides=smoke_overrides("rec_ippo"), step=8,
    )
    meta = read_policy_meta(d)
    assert meta["system"] == "rec_ippo" and meta["env"] == "matrix_game"
    assert meta["tree"] == "train_state"

    _, system2, train2 = load_policy(d)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        ),
        st.train.params, train2.params,
    )
    ev_key = jax.random.key(5)
    before = evaluate(system, st.train, ev_key, num_episodes=4, num_envs=4)
    after = evaluate(system2, train2, ev_key, num_episodes=4, num_envs=4)
    np.testing.assert_array_equal(
        np.asarray(before.episode_return), np.asarray(after.episode_return)
    )


def test_policy_checkpoint_per_seed_lanes(tmp_path):
    _, system = _tiny("ippo")
    st, _ = train_anakin(system, jax.random.key(0), 8, 4, num_seeds=2)
    d = str(tmp_path / "pol")
    save_policy(
        d, "ippo", "matrix_game", st.train,
        config_overrides=smoke_overrides("ippo"), num_seeds=2, step=8,
    )
    for s in range(2):
        _, _, train_s = load_policy(d, seed=s)
        lane = jax.tree_util.tree_map(lambda x: jnp.asarray(x)[s], st.train)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            ),
            lane.params, train_s.params,
        )
    with pytest.raises(ValueError):
        load_policy(d, seed=2)


def test_policy_meta_rejects_foreign_directories(tmp_path):
    d = tmp_path / "not_a_policy"
    d.mkdir()
    (d / "policy.json").write_text(json.dumps({"format": "something-else"}))
    with pytest.raises(ValueError):
        read_policy_meta(str(d))
