"""Logical-axis sharding rules (divisibility dropping, profiles) and the
ambient-mesh fallbacks (`enter_mesh` / `with_logical_constraint` on jax
releases without the `jax.set_mesh` API)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding
from repro.distributed.sharding import (
    DEFAULT_RULES,
    FSDP_TP_RULES,
    enter_mesh,
    logical_to_spec,
    rules_for,
    tree_shardings,
    with_logical_constraint,
)

# A host-only mesh over the single CPU device would have size-1 axes, which
# can't exercise divisibility. Use an abstract mesh instead.


def abstract_mesh(sizes, names):
    if hasattr(jax.sharding, "AxisType"):
        return jax.sharding.AbstractMesh(
            sizes, names, axis_types=(jax.sharding.AxisType.Auto,) * len(names)
        )
    # older jax: AbstractMesh takes ((name, size), ...) pairs
    return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


def make_mesh():
    return abstract_mesh((2, 4), ("data", "model"))


def test_basic_mapping():
    mesh = make_mesh()
    spec = logical_to_spec(("vocab", "embed"), DEFAULT_RULES, mesh)
    assert spec == P("model")


def test_batch_uses_pod_and_data():
    mesh = abstract_mesh((2, 2, 4), ("pod", "data", "model"))
    spec = logical_to_spec(("batch", None, "embed"), DEFAULT_RULES, mesh)
    assert spec == P(("pod", "data"))


def test_non_divisible_axis_dropped():
    mesh = make_mesh()
    # 8 kv heads on a 4-way model axis: fine; 6 heads: dropped
    assert logical_to_spec(("kv_heads",), DEFAULT_RULES, mesh, shape=(8,)) == P("model")
    assert logical_to_spec(("kv_heads",), DEFAULT_RULES, mesh, shape=(6,)) == P()


def test_axis_never_reused_within_spec():
    mesh = make_mesh()
    # both vocab and ffn map to "model": second use must drop
    spec = logical_to_spec(("vocab", "ffn"), DEFAULT_RULES, mesh)
    assert spec == P("model")


def test_fsdp_profile_shards_embed_over_data():
    mesh = make_mesh()
    spec = logical_to_spec(("embed", "ffn"), FSDP_TP_RULES, mesh, shape=(8, 8))
    assert spec == P("data", "model")
    # but activations with a batch dim keep data for the batch
    spec = logical_to_spec(("batch", None, "embed"), FSDP_TP_RULES, mesh, shape=(8, 4, 8))
    assert spec == P("data")


def test_tree_shardings_with_shapes():
    mesh = make_mesh()
    axes = {"w": ("embed", "ffn"), "b": ("ffn",)}
    shapes = {
        "w": jax.ShapeDtypeStruct((16, 8), jax.numpy.float32),
        "b": jax.ShapeDtypeStruct((6,), jax.numpy.float32),  # 6 % 4 != 0
    }
    out = tree_shardings(axes, mesh, DEFAULT_RULES, shapes)
    assert out["w"].spec == P(None, "model")
    assert out["b"].spec == P()


def test_unknown_profile_raises():
    with pytest.raises(KeyError):
        rules_for("nope")


def test_actors_axis_rule_maps_to_data():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    assert logical_to_spec(("actors",), DEFAULT_RULES, mesh) == P("data")


# ------------------------------------------------- ambient-mesh fallbacks
# These run the real construction paths on whatever jax is installed: on
# releases without jax.set_mesh, enter_mesh falls back to the legacy Mesh
# context manager and _ambient_mesh reads the legacy thread resources.


def device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def test_enter_mesh_installs_ambient_mesh():
    assert sharding._ambient_mesh() is None or sharding._ambient_mesh().empty
    with enter_mesh(device_mesh()):
        ambient = sharding._ambient_mesh()
        assert ambient is not None and not ambient.empty
        assert tuple(ambient.axis_names) == ("data",)
    post = sharding._ambient_mesh()
    assert post is None or post.empty


def test_with_logical_constraint_is_noop_outside_mesh():
    x = jnp.arange(8.0)
    y = with_logical_constraint(x, ("batch",))
    assert y is x  # literally untouched, not just equal


def test_with_logical_constraint_applies_inside_mesh():
    x = jnp.arange(8.0).reshape(4, 2)

    @jax.jit
    def f(x):
        return with_logical_constraint(x, ("batch", None)) * 2

    with enter_mesh(device_mesh()):
        np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x) * 2)


def test_legacy_fallback_path_used_when_api_missing(monkeypatch):
    """Force the legacy branch so it stays covered on every jax release."""
    monkeypatch.setattr(sharding, "_HAS_AMBIENT_MESH_API", False)
    mesh = device_mesh()
    ctx = enter_mesh(mesh)
    assert ctx is mesh  # legacy: Mesh itself is the context manager
    with ctx:
        ambient = sharding._ambient_mesh()
        assert ambient is not None and not ambient.empty
