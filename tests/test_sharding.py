"""Logical-axis sharding rules (divisibility dropping, profiles)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    DEFAULT_RULES,
    FSDP_TP_RULES,
    logical_to_spec,
    rules_for,
    tree_shardings,
)

# A host-only mesh over the single CPU device would have size-1 axes, which
# can't exercise divisibility. Use an abstract mesh instead.


def abstract_mesh(sizes, names):
    if hasattr(jax.sharding, "AxisType"):
        return jax.sharding.AbstractMesh(
            sizes, names, axis_types=(jax.sharding.AxisType.Auto,) * len(names)
        )
    # older jax: AbstractMesh takes ((name, size), ...) pairs
    return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


def make_mesh():
    return abstract_mesh((2, 4), ("data", "model"))


def test_basic_mapping():
    mesh = make_mesh()
    spec = logical_to_spec(("vocab", "embed"), DEFAULT_RULES, mesh)
    assert spec == P("model")


def test_batch_uses_pod_and_data():
    mesh = abstract_mesh((2, 2, 4), ("pod", "data", "model"))
    spec = logical_to_spec(("batch", None, "embed"), DEFAULT_RULES, mesh)
    assert spec == P(("pod", "data"))


def test_non_divisible_axis_dropped():
    mesh = make_mesh()
    # 8 kv heads on a 4-way model axis: fine; 6 heads: dropped
    assert logical_to_spec(("kv_heads",), DEFAULT_RULES, mesh, shape=(8,)) == P("model")
    assert logical_to_spec(("kv_heads",), DEFAULT_RULES, mesh, shape=(6,)) == P()


def test_axis_never_reused_within_spec():
    mesh = make_mesh()
    # both vocab and ffn map to "model": second use must drop
    spec = logical_to_spec(("vocab", "ffn"), DEFAULT_RULES, mesh)
    assert spec == P("model")


def test_fsdp_profile_shards_embed_over_data():
    mesh = make_mesh()
    spec = logical_to_spec(("embed", "ffn"), FSDP_TP_RULES, mesh, shape=(8, 8))
    assert spec == P("data", "model")
    # but activations with a batch dim keep data for the batch
    spec = logical_to_spec(("batch", None, "embed"), FSDP_TP_RULES, mesh, shape=(8, 4, 8))
    assert spec == P("data")


def test_tree_shardings_with_shapes():
    mesh = make_mesh()
    axes = {"w": ("embed", "ffn"), "b": ("ffn",)}
    shapes = {
        "w": jax.ShapeDtypeStruct((16, 8), jax.numpy.float32),
        "b": jax.ShapeDtypeStruct((6,), jax.numpy.float32),  # 6 % 4 != 0
    }
    out = tree_shardings(axes, mesh, DEFAULT_RULES, shapes)
    assert out["w"].spec == P(None, "model")
    assert out["b"].spec == P()


def test_unknown_profile_raises():
    with pytest.raises(KeyError):
        rules_for("nope")
