"""Data pipeline + audio delay-pattern property tests."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.tokens import SyntheticTokenDataset
from repro.models.audio import apply_delay_pattern, revert_delay_pattern


def test_synthetic_dataset_deterministic():
    a = SyntheticTokenDataset(100, 16, 4, seed=3)
    b = SyntheticTokenDataset(100, 16, 4, seed=3)
    ra, rb = np.random.default_rng(0), np.random.default_rng(0)
    xa, xb = a.sample(ra), b.sample(rb)
    np.testing.assert_array_equal(xa["tokens"], xb["tokens"])
    np.testing.assert_array_equal(xa["labels"], xb["labels"])


def test_synthetic_dataset_has_bigram_structure():
    ds = SyntheticTokenDataset(50, 256, 8, seed=0, structure=0.9)
    rng = np.random.default_rng(1)
    batch = ds.sample(rng)
    toks, labels = batch["tokens"], batch["labels"]
    # labels are next tokens
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
    # ~90% of transitions follow the permutation rule
    follows = (ds.perm[toks[:, :-1]] == toks[:, 1:]).mean()
    assert follows > 0.7, follows


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(4, 20),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_delay_pattern_roundtrip(b, s, k, seed):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, 100, (b, s, k)), jnp.int32)
    pad = 101
    delayed = apply_delay_pattern(toks, pad)
    back = revert_delay_pattern(delayed, pad)
    # valid region (first s-k+1 frames of each codebook) is exactly restored
    for kk in range(k):
        np.testing.assert_array_equal(
            np.asarray(back[:, : s - kk, kk]), np.asarray(toks[:, : s - kk, kk])
        )
    # delayed codebook k has k pads at the front
    for kk in range(k):
        assert (np.asarray(delayed[:, :kk, kk]) == pad).all()
