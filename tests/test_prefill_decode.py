"""Prefill+decode must agree with the full training forward for every arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.models.layers import rmsnorm


def full_logits(params, batch, cfg):
    h, _ = M._embed_tokens(params, batch, cfg)
    h, _ = M._run_layers_train(params, h, cfg)
    h = rmsnorm(params["final_norm"], h)
    w = M._unembed_weight(params, cfg)
    if cfg.num_codebooks:
        return jnp.einsum("bsd,kdv->bskv", h, w)
    return h @ w


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 32

    if cfg.arch_type == "audio":
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1, cfg.num_codebooks)), jnp.int32)
        prompt = {"tokens": toks[:, :S]}
        next_tok = toks[:, S : S + 1]
    elif cfg.arch_type == "vlm":
        V = cfg.vision_tokens
        T = S - V
        txt = jnp.asarray(rng.integers(0, cfg.vocab, (B, T + 1)), jnp.int32)
        ve = jnp.asarray(rng.normal(size=(B, V, cfg.d_model)), jnp.float32)
        prompt = {"tokens": txt[:, :T], "vision_embeds": ve}
        next_tok = txt[:, T : T + 1]
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
        prompt = {"tokens": toks[:, :S]}
        next_tok = toks[:, S : S + 1]

    last_logits, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, max_len=S + 4)
    )(params, prompt)
    fl = full_logits(params, prompt, cfg)
    np.testing.assert_allclose(
        np.asarray(last_logits[:, -1]), np.asarray(fl[:, -1]), rtol=2e-4, atol=2e-4
    )

    dec_logits, cache2 = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))(
        params, cache, next_tok
    )
    assert (np.asarray(cache2["pos"]) == S + 1).all()
    batch2 = dict(prompt)
    batch2["tokens"] = jnp.concatenate([prompt["tokens"], next_tok], axis=1)
    fl2 = full_logits(params, batch2, cfg)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(fl2[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_sliding_window_ring_cache_drops_old_tokens():
    """With a window smaller than the prompt, decode must equal a windowed
    oracle, not the full-attention one."""
    cfg = get_smoke_config("minitron-8b")  # attn_window=64 in smoke
    import dataclasses

    cfg = dataclasses.replace(cfg, attn_window=16)
    params = M.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    B, S = 1, 40
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    prompt = {"tokens": toks[:, :S]}

    _, cache = jax.jit(lambda p, b: M.prefill(p, b, cfg, max_len=S + 4))(params, prompt)
    # window cache capacity = attn_window, not prompt length
    assert cache["kv"]["k"].shape[2] == 16
    dec_logits, _ = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))(
        params, cache, toks[:, S : S + 1]
    )
    batch2 = {"tokens": toks}
    fl = full_logits(params, batch2, cfg)  # windowed oracle via cfg.attn_window
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(fl[:, -1]), rtol=2e-3, atol=2e-3
    )
