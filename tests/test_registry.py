"""Registry round-trip: every system builds and runs on every compatible env.

The acceptance surface of the unified System API: each registered system,
on each env its spec supports, must survive fused `train_anakin` iterations
(including at least one trainer update) and one fused `evaluate` call.
"""
import jax
import numpy as np
import pytest

from repro.core.system import train_anakin
from repro.envs import REGISTRY as ENVS
from repro.eval import evaluate
from repro.systems import REGISTRY, compatibility, make_pair, make_system

# tiny env instances so jit compiles stay cheap
ENV_KWARGS = {
    "matrix_game": {"horizon": 6},
    "spread": {"horizon": 8},
    "speaker_listener": {"horizon": 8},
    "smax_lite": {"horizon": 10},
    "robot_warehouse": {
        "horizon": 8, "grid_size": 6, "num_shelves": 4, "num_requests": 2,
    },
    "lbf": {"horizon": 8, "grid_size": 5, "num_food": 2},
}

# tiny configs so at least one update fires within a handful of iterations
SYS_OVERRIDES = {
    "madqn": dict(buffer_capacity=64, min_replay=4, batch_size=4),
    "madqn-fp": dict(buffer_capacity=64, min_replay=4, batch_size=4),
    "vdn": dict(buffer_capacity=64, min_replay=4, batch_size=4),
    "qmix": dict(buffer_capacity=64, min_replay=4, batch_size=4),
    "maddpg": dict(buffer_capacity=64, min_replay=4, batch_size=4),
    "mad4pg": dict(buffer_capacity=64, min_replay=4, batch_size=4),
    "ippo": dict(rollout_len=4, epochs=1, num_minibatches=2),
    "mappo": dict(rollout_len=4, epochs=1, num_minibatches=2),
    "rec_ippo": dict(rollout_len=4, epochs=1, num_minibatches=2, hidden_sizes=(16, 16)),
    "rec_mappo": dict(rollout_len=4, epochs=1, num_minibatches=2, hidden_sizes=(16, 16)),
    # window_len 3, stride 2, 2 envs: 2 windows stored by step 3 -> the
    # seq-replay gate opens inside the 4-iteration round-trip
    "rec_madqn": dict(hidden_sizes=(16,), seq_len=2, burn_in=1,
                      buffer_capacity=16, batch_size=2, min_windows=2),
    "dial": dict(rollout_len=4),
    "rial": dict(rollout_len=4),
}


@pytest.mark.parametrize("system_name", sorted(REGISTRY))
def test_registry_roundtrip(system_name):
    ran = 0
    for env_name in sorted(ENVS):
        reason = compatibility(system_name, env_name)
        if reason is not None:
            continue
        env, system = make_pair(
            system_name,
            env_name,
            env_kwargs=ENV_KWARGS.get(env_name),
            **SYS_OVERRIDES.get(system_name, {}),
        )
        st, metrics = train_anakin(system, jax.random.key(0), 4, num_envs=2)
        assert int(st.train.steps) >= 1, (system_name, env_name)  # updated
        assert np.isfinite(np.asarray(metrics["reward"])).all()
        ev = evaluate(system, st.train, jax.random.key(1), num_episodes=2, num_envs=2)
        assert ev.episode_return.shape == (2,)
        assert np.isfinite(np.asarray(ev.episode_return)).all()
        assert set(ev.agent_returns) == set(system.spec.agent_ids)
        ran += 1
    assert ran >= 1, f"{system_name} compatible with no registered env"


def test_every_acceptance_system_is_registered():
    for name in ("madqn", "vdn", "qmix", "maddpg", "mad4pg", "ippo", "mappo", "dial"):
        assert name in REGISTRY


def test_make_system_rejects_incompatible_pairs():
    from repro.envs import MatrixGame

    with pytest.raises(ValueError, match="continuous"):
        make_system("maddpg", MatrixGame())
    with pytest.raises(KeyError):
        make_system("not_a_system", MatrixGame())


def test_forced_continuous_on_discrete_only_env_is_rejected():
    # user-forced continuous mode on an env without one: clear error from
    # make_pair, reason (not a crash) from compatibility
    with pytest.raises(ValueError, match="continuous"):
        make_pair("vdn", "matrix_game", env_kwargs={"continuous": True})
    reason = compatibility("vdn", "matrix_game", env_kwargs={"continuous": True})
    assert reason is not None and "continuous" in reason


def test_compatibility_matrix_is_spec_driven():
    # continuous systems pair only with envs that offer a continuous mode
    assert compatibility("maddpg", "spread") is None  # auto-continuous
    assert compatibility("maddpg", "matrix_game") is not None
    # discrete systems keep spread in its default discrete mode
    assert compatibility("vdn", "spread") is None
    # shared-weight recurrent systems need homogeneous agents
    assert compatibility("dial", "speaker_listener") is not None
    assert compatibility("dial", "switch_game") is None


def test_make_system_overrides_reach_config():
    from repro.envs import MatrixGame

    system = make_system("ippo", MatrixGame(), rollout_len=8)
    buf = system.init_buffer(2)
    assert jax.tree_util.tree_leaves(buf.storage)[0].shape[0] == 8


def test_distributed_axis_flows_through_make_system():
    from repro.envs import MatrixGame

    # builds without error and still trains (pmean is a no-op on 1 device)
    system = make_system(
        "ippo", MatrixGame(), distributed_axis=None, rollout_len=4,
        epochs=1, num_minibatches=1,
    )
    st, _ = train_anakin(system, jax.random.key(0), 4, num_envs=2)
    assert int(st.train.steps) == 1
