"""Environment API invariants: one parametrized spec-conformance suite.

Every env in ``repro.envs.REGISTRY`` (including wrapped registry stacks)
passes the same checks — reset/step outputs match the `EnvSpec` shapes and
dtypes, vmap across copies equals independent envs, determinism under a
fixed key, and auto-reset emits FIRST after the inner LAST — replacing
per-env shape boilerplate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.envs import AutoReset, REGISTRY, make_env
from repro.envs.api import StepType

# small instances so the conformance scans stay cheap
SMALL_KWARGS = {
    "robot_warehouse": {"horizon": 12, "grid_size": 6, "num_shelves": 4},
    "lbf": {"horizon": 12, "grid_size": 5, "num_food": 2},
}


def small_env(name):
    return make_env(name, **SMALL_KWARGS.get(name, {}))


def random_actions(spec, rng):
    acts = {}
    for a in spec.agent_ids:
        s = spec.actions[a]
        if hasattr(s, "num_values"):
            acts[a] = jnp.asarray(rng.integers(0, s.num_values), jnp.int32)
        else:
            acts[a] = jnp.asarray(rng.uniform(-1, 1, s.shape), jnp.float32)
    return acts


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_spec_conformance(name):
    env = small_env(name)
    spec = env.spec()
    state, ts = jax.jit(env.reset)(jax.random.key(0))
    assert int(ts.step_type) == StepType.FIRST
    assert float(ts.discount) == 1.0
    assert set(ts.observation) == set(spec.agent_ids) == set(ts.reward)
    rng = np.random.default_rng(0)
    step = jax.jit(env.step)
    for _ in range(5):
        state, ts = step(state, random_actions(spec, rng))
        for a in spec.agent_ids:
            ob = jnp.asarray(ts.observation[a])
            assert ob.shape == spec.observations[a].shape
            assert ob.dtype == spec.observations[a].dtype
            assert np.isfinite(np.asarray(ob)).all()
            assert np.isfinite(float(ts.reward[a]))
        assert float(ts.discount) in (0.0, 1.0)
        gs = env.global_state(state)
        assert gs.shape == spec.state.shape
        assert jnp.asarray(gs).dtype == spec.state.dtype


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_determinism_same_key(name):
    env = small_env(name)
    spec = env.spec()
    rng = np.random.default_rng(1)
    acts = random_actions(spec, rng)
    outs = []
    for _ in range(2):
        state, ts = env.reset(jax.random.key(7))
        state, ts = env.step(state, acts)
        outs.append(jax.tree_util.tree_map(np.asarray, ts))
    a, b = outs
    jax.tree_util.tree_map(np.testing.assert_array_equal, a, b)


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_vmap_matches_single(name):
    """Vectorised env == N independent envs (the Anakin correctness premise)."""
    env = small_env(name)
    spec = env.spec()
    keys = jax.random.split(jax.random.key(3), 4)
    rng = np.random.default_rng(2)
    acts = random_actions(spec, rng)
    bacts = jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (4,) + x.shape), acts)

    bstate, bts = jax.vmap(env.reset)(keys)
    bstate, bts = jax.vmap(env.step)(bstate, bacts)
    for i in (0, 3):
        s, ts = env.reset(keys[i])
        s, ts = env.step(s, acts)
        for a in spec.agent_ids:
            np.testing.assert_allclose(
                np.asarray(bts.observation[a][i]), np.asarray(ts.observation[a]),
                rtol=1e-6, atol=1e-6,
            )


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_auto_reset_emits_first_after_last(name):
    """Wrapped in AutoReset, the inner LAST is followed by a fused FIRST.

    The merged boundary timestep carries step_type FIRST (the new episode's
    reset observation) with the terminal discount, LAST never surfaces, and
    the episode stream keeps going past the boundary.
    """
    env = AutoReset(small_env(name))
    spec = env.spec()
    state, ts = env.reset(jax.random.key(5))
    rng = np.random.default_rng(4)
    step = jax.jit(env.step)
    boundaries = 0
    for _ in range(int(env.horizon) + 3):
        state, ts = step(state, random_actions(spec, rng))
        kind = int(ts.step_type)
        assert kind != StepType.LAST  # auto-reset swallows LAST...
        if kind == StepType.FIRST:
            boundaries += 1  # ...and emits the next episode's FIRST
            assert float(ts.discount) == 0.0  # terminal discount rides along
            for a in spec.agent_ids:  # reset observation, right spec
                assert ts.observation[a].shape == spec.observations[a].shape
    # every env terminates within its horizon, so stepping horizon+3 times
    # must have crossed at least one boundary
    assert boundaries >= 1


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_switch_game_reward_logic(n_agents, seed):
    """Reward is only ever paid on a Tell, and is +-1."""
    from repro.envs import SwitchGame

    env = SwitchGame(num_agents=n_agents)
    state, ts = env.reset(jax.random.key(seed))
    # everyone says Tell on day one: correct iff all have been in the room
    acts = {a: jnp.asarray(1, jnp.int32) for a in env.agent_ids}
    all_visited = bool(jnp.all(state.has_been))
    state, ts = env.step(state, acts)
    r = float(ts.reward["agent_0"])
    assert r == (1.0 if all_visited else -1.0)
    assert int(ts.step_type) == StepType.LAST


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40))
def test_episodes_terminate_within_horizon(seed, steps):
    from repro.envs import Spread

    env = Spread(num_agents=2, horizon=10)
    state, ts = env.reset(jax.random.key(seed))
    acts = {a: jnp.asarray(0, jnp.int32) for a in env.agent_ids}
    for t in range(min(steps, 10)):
        state, ts = env.step(state, acts)
    if steps >= 10:
        assert int(ts.step_type) == StepType.LAST
        assert float(ts.discount) == 0.0
