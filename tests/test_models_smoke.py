"""Per-architecture smoke tests: reduced variants, one fwd/train step on CPU.

The assignment requires: instantiate a REDUCED variant of each assigned
family (<=2 layers for dense, d_model<=512, <=4 experts) and run one
forward/train step asserting output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import model as M


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.arch_type == "audio":
        toks = rng.integers(0, cfg.vocab, (B, S + 1, cfg.num_codebooks)).astype(np.int32)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
    if cfg.arch_type == "vlm":
        T = S - cfg.vision_tokens
        toks = rng.integers(0, cfg.vocab, (B, T + 1)).astype(np.int32)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "vision_embeds": jnp.asarray(
                rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)),
                cfg.activation_dtype,
            ),
        }
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    # reduced config stays in the same family as the full one
    assert cfg.arch_type == get_config(arch).arch_type


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(jax.random.key(0), cfg)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: M.forward_train(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    for k, v in metrics.items():
        assert np.isfinite(float(v)), (arch, k)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_updates_params(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(jax.random.key(0), cfg)
    opt, train_step = make_train_step(cfg, lr=1e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg)
    new_params, new_opt, metrics = jax.jit(train_step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # at least one parameter changed, none became NaN
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params
    )
    assert any(jax.tree_util.tree_leaves(changed)), arch
    finite = jax.tree_util.tree_map(
        lambda a: bool(jnp.all(jnp.isfinite(a.astype(jnp.float32)))), new_params
    )
    assert all(jax.tree_util.tree_leaves(finite)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_decreases_over_steps(arch):
    """A few steps on a fixed batch must reduce the loss (overfit check)."""
    cfg = get_smoke_config(arch)
    params = M.init_model(jax.random.key(0), cfg)
    opt, train_step = make_train_step(cfg, lr=3e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg)
    step = jax.jit(train_step)
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


def test_full_configs_match_assignment_table():
    """Exact assigned hyperparameters (spot-check every arch)."""
    rows = {
        "minitron-8b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=16384, vocab=256000),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336, vocab=32000),
        "internlm2-1.8b": dict(num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, d_ff=8192, vocab=92544),
        "olmoe-1b-7b": dict(num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1024, vocab=50304, num_experts=64, top_k=8),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, d_ff=2048, vocab=163840, num_experts=384, top_k=8),
        "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336, vocab=49152),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096, d_ff=0, vocab=65024, ssm_state=16),
        "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, d_ff=10240, vocab=32000, ssm_state=64),
        "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192, vocab=2048, num_codebooks=4),
        "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8, d_ff=53248, vocab=128256),
    }
    for arch, expect in rows.items():
        cfg = get_config(arch)
        for k, v in expect.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        assert cfg.source, arch  # every config cites its source
