"""Continuous-batching engine: outputs must match sequential generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def sequential_generate(cfg, params, prompt, n):
    """Reference: one stream, prefill + greedy decode."""
    batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
    logits, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, max_len=len(prompt) + n + 4)
    )(params, batch)
    out = [int(jnp.argmax(logits[0, -1]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    for _ in range(n - 1):
        logits, cache = step(params, cache, tok)
        out.append(int(jnp.argmax(logits[0, 0])))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "falcon-mamba-7b"])
def test_engine_matches_sequential(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, (12,)).astype(np.int32),
        rng.integers(0, cfg.vocab, (9,)).astype(np.int32),   # ragged lengths
        rng.integers(0, cfg.vocab, (15,)).astype(np.int32),
    ]
    n_new = 6

    engine = ServingEngine(cfg, params, max_slots=2, prompt_capacity=16,
                           max_new_tokens=n_new)
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    finished = engine.run_until_drained()
    assert len(finished) == 3
    outputs = {r.uid: r.output for r in finished}

    for i, p in enumerate(prompts):
        ref = sequential_generate(cfg, params, p, n_new)
        assert outputs[i] == ref, (arch, i, outputs[i], ref)


def test_engine_continuous_refill():
    """More requests than slots: the queue drains via slot reuse."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = M.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    engine = ServingEngine(cfg, params, max_slots=2, prompt_capacity=8,
                           max_new_tokens=3)
    for i in range(5):
        engine.submit(
            Request(uid=i, prompt=rng.integers(0, cfg.vocab, (5,)).astype(np.int32),
                    max_new_tokens=3)
        )
    finished = engine.run_until_drained()
    assert sorted(r.uid for r in finished) == [0, 1, 2, 3, 4]
    assert all(len(r.output) == 3 for r in finished)
