import functools
import inspect
import os
import random
import sys
import types
import zlib

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 CPU device.
# Multi-device tests (tests/test_distributed.py, tests/test_dryrun.py) spawn
# subprocesses that set --xla_force_host_platform_device_count themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------- hypothesis
# The property tests import `hypothesis`, which is not in the container.
# Install a minimal deterministic stand-in *before collection* so those
# modules import: @given re-runs the test over a fixed number of examples
# drawn from a per-test seeded RNG (fixed seeds, reproducible across runs) —
# the parametrize-over-fixed-seeds rewrite, done once centrally.
try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _MAX_EXAMPLES_CAP = 10  # keep stubbed property tests fast

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))
            ]
        )

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.randint(0, 1)))

    def _given(*arg_strats, **kwarg_strats):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", None) or getattr(
                    fn, "_stub_max_examples", _MAX_EXAMPLES_CAP
                )
                n = min(n, _MAX_EXAMPLES_CAP)
                # deterministic per-test seed: fixed examples, every run
                rng = random.Random(zlib.adler32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = [s.draw(rng) for s in arg_strats]
                    drawn_kw = {k: s.draw(rng) for k, s in kwarg_strats.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # hide strategy-filled params from pytest's fixture resolution
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            covered = set(names[: len(arg_strats)]) | set(kwarg_strats)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for p in sig.parameters.values() if p.name not in covered
                ]
            )
            del wrapper.__wrapped__
            return wrapper

        return decorate

    def _settings(max_examples=_MAX_EXAMPLES_CAP, deadline=None, **_ignored):
        def decorate(fn):
            fn._stub_max_examples = max_examples
            return fn

        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.lists = _lists
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
