"""Dry-run lowering tests (subprocess: needs 512 host devices).

The full 40-pair x 2-mesh sweep lives in the benchmark harness; here we
prove the machinery on one representative arch per family, both meshes.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_dryrun(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=ROOT,
    )


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,shape",
    [
        ("internlm2-1.8b", "train_4k"),     # dense
        ("olmoe-1b-7b", "decode_32k"),      # moe
        ("falcon-mamba-7b", "long_500k"),   # ssm
        ("zamba2-2.7b", "prefill_32k"),     # hybrid
        ("musicgen-large", "decode_32k"),   # audio
        ("llava-next-mistral-7b", "train_4k"),  # vlm
    ],
)
def test_single_pod_lowering(arch, shape):
    r = run_dryrun(["--arch", arch, "--shape", shape])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "[OK]" in r.stdout


@pytest.mark.slow
def test_multi_pod_lowering():
    r = run_dryrun(["--arch", "internlm2-1.8b", "--shape", "train_4k", "--multi-pod"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "2x16x16" in r.stdout
