"""The shared memory-core protocol: ScannedRNN, carry resets, recurrent PPO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.system import (
    _one_iteration,
    _training_env,
    init_system_state,
    train_anakin,
)
from repro.envs import MatrixGame
from repro.envs.api import StepType
from repro.eval import evaluate
from repro.nn import ScannedRNN, reset_carry, window_start_carry
from repro.systems.onpolicy import PPOConfig, make_rec_ippo, make_rec_mappo

CFG = PPOConfig(rollout_len=8, epochs=1, num_minibatches=2, hidden_sizes=(16, 16))


def _rec_ippo(horizon=6):
    return make_rec_ippo(MatrixGame(horizon=horizon), CFG)


# ------------------------------------------------------------- ScannedRNN


def test_scanned_rnn_reset_equals_fresh_start():
    """A reset at row k makes rows k.. identical to an unroll starting at k."""
    core = ScannedRNN(4, 8)
    params = core.init(jax.random.key(0))
    xs = jax.random.normal(jax.random.key(1), (10, 3, 4))
    resets = jnp.zeros((10, 3), bool).at[6].set(True)
    _, ys = core.unroll(params, core.initial_carry((3,)), xs, resets)
    _, ys_tail = core.unroll(params, core.initial_carry((3,)), xs[6:])
    np.testing.assert_allclose(np.asarray(ys[6:]), np.asarray(ys_tail), rtol=1e-6)
    # and without the reset the histories genuinely differ
    _, ys_nr = core.unroll(params, core.initial_carry((3,)), xs)
    assert np.abs(np.asarray(ys_nr[6:]) - np.asarray(ys_tail)).max() > 1e-4


def test_reset_carry_masks_only_reset_lanes():
    carry = {"h": jnp.ones((4, 5)), "m": jnp.full((4, 2), 3.0)}
    reset = jnp.array([True, False, True, False])
    out = reset_carry(carry, reset)
    np.testing.assert_array_equal(np.asarray(out["h"][0]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["h"][1]), 1.0)
    np.testing.assert_array_equal(np.asarray(out["m"][2]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["m"][3]), 3.0)


def test_window_start_carry_stored_vs_zero_paths():
    init = lambda bs: {"h": jnp.zeros((*bs, 3))}
    stored = {"carry_in": {"h": jnp.arange(12.0).reshape(2, 2, 3)}}
    out = window_start_carry(stored, init, (2,))
    np.testing.assert_array_equal(
        np.asarray(out["h"]), np.arange(6.0).reshape(2, 3)
    )
    # no stored carries -> the documented R2D2 zero start-state path
    out = window_start_carry({"msgs": ()}, init, (2,))
    np.testing.assert_array_equal(np.asarray(out["h"]), np.zeros((2, 3)))


# ----------------------------------------- carry resets at episode bounds


def test_carry_resets_at_autoreset_first_mid_rollout():
    """Auto-reset FIRST boundaries zero the executor carry inside the scan.

    horizon=3 < rollout_len=8, so episode boundaries fall mid-rollout: at
    every iteration whose timestep is a merged FIRST, that env lane's
    hidden state must be zero (while mid-episode lanes stay nonzero), and
    the stored ``extras["carry_in"]`` rows at FIRST rows must be zeros.
    """
    system = _rec_ippo(horizon=3)
    tenv = _training_env(system.env)
    st = init_system_state(system, jax.random.key(0), 3, train_env=tenv)
    step = jax.jit(lambda s: _one_iteration(system, tenv, s, s.key))

    saw_first = saw_mid_nonzero = False
    for _ in range(7):
        st, _ = step(st)
        first = np.asarray(st.timestep.step_type == StepType.FIRST)
        for h in jax.tree_util.tree_leaves(st.carry.hidden):
            h = np.asarray(h)
            if first.any():
                saw_first = True
                np.testing.assert_array_equal(h[first], 0.0)
            if (~first).any() and np.abs(h[~first]).max() > 0:
                saw_mid_nonzero = True
    assert saw_first, "no auto-reset boundary hit in 7 iterations"
    assert saw_mid_nonzero, "hidden state never left zero mid-episode"

    # the stored rows agree: FIRST rows carry zeroed memory
    stored = st.buffer.storage
    t = int(st.buffer.t)
    first_rows = np.asarray(stored.step_type[:t] == StepType.FIRST)
    assert first_rows.any()
    for h in jax.tree_util.tree_leaves(stored.extras["carry_in"].hidden):
        np.testing.assert_array_equal(np.asarray(h[:t])[first_rows], 0.0)


# ------------------------------------------------------- recurrent eval


def test_recurrent_evaluate_invariant_to_chunking():
    """Greedy recurrent eval returns don't depend on episode batching.

    MatrixGame resets deterministically and greedy actions are
    key-independent, so the same params must score identically whether the
    6 episodes run as one vmapped batch, two rounds of 3, or solo — any
    cross-lane leak through the carry (wrong batching) breaks this.
    """
    system = _rec_ippo()
    train = system.init_train(jax.random.key(0))
    runs = {
        n: evaluate(
            system, train, jax.random.key(1), num_episodes=6, num_envs=n
        )
        for n in (6, 3, 1)
    }
    for n in (3, 1):
        np.testing.assert_array_equal(
            np.asarray(runs[6].episode_return), np.asarray(runs[n].episode_return)
        )
        np.testing.assert_array_equal(
            np.asarray(runs[6].episode_length), np.asarray(runs[n].episode_length)
        )


def test_recurrent_evaluate_vmapped_over_seeds_matches_standalone():
    """The seed-batched recurrent evaluator reproduces per-seed solo runs."""
    system = make_rec_mappo(MatrixGame(horizon=6), CFG)
    keys = jnp.stack([jax.random.key(s) for s in (0, 1)])
    trains = jax.vmap(system.init_train)(keys)
    batched = evaluate(
        system, trains, keys, num_episodes=4, num_envs=2, num_seeds=2
    )
    assert batched.episode_return.shape == (2, 4)
    for i in range(2):
        lane = jax.tree_util.tree_map(lambda x: x[i], trains)
        solo = evaluate(system, lane, keys[i], num_episodes=4, num_envs=2)
        np.testing.assert_array_equal(
            np.asarray(solo.episode_return), np.asarray(batched.episode_return)[i]
        )


def test_recurrent_minibatching_consumes_every_sequence():
    """Sequence minibatching must train on *all* collected sequences.

    With num_envs=6 and num_minibatches=4 a naive ``B // n_mb`` split
    drops two whole sequences per epoch; the divisor fallback (n_mb=3)
    must not. Perturbing any one stored sequence's rewards has to change
    the resulting update — under a dropping split, the excluded sequences
    produce bitwise-identical params.
    """
    from repro.core.types import Transition

    env = MatrixGame(horizon=6)
    cfg = PPOConfig(rollout_len=4, epochs=1, num_minibatches=4,
                    entropy_coef=0.0, hidden_sizes=(8, 8))
    system = make_rec_ippo(env, cfg)
    train = system.init_train(jax.random.key(0))
    B = 6
    buf = system.init_buffer(B)
    key = jax.random.key(1)
    env_state, ts = jax.vmap(env.reset)(jax.random.split(key, B))
    carry = system.initial_carry((B,))
    for _ in range(cfg.rollout_len):
        key, k_act = jax.random.split(key)
        gs = jax.vmap(env.global_state)(env_state)
        actions, carry, extras = system.select_actions(
            train, ts.observation, gs, carry, k_act
        )
        env_state, new_ts = jax.vmap(env.step)(env_state, actions)
        buf = system.observe(buf, Transition(
            obs=ts.observation, actions=actions, rewards=new_ts.reward,
            discount=new_ts.discount, next_obs=new_ts.observation,
            state=gs, next_state=jax.vmap(env.global_state)(env_state),
            extras=extras, step_type=ts.step_type,
        ))
        ts = new_ts
    assert bool(system.can_sample(buf))

    update = jax.jit(system.update)

    def flat_params(tr):
        return np.concatenate([
            np.asarray(x).ravel() for x in jax.tree_util.tree_leaves(tr.params)
        ])

    base = flat_params(update(train, buf, jax.random.key(2))[0])
    for b in range(B):
        rewards = {
            a: r.at[:, b].add(100.0) for a, r in buf.storage.rewards.items()
        }
        buf_b = buf._replace(storage=buf.storage._replace(rewards=rewards))
        perturbed = flat_params(update(train, buf_b, jax.random.key(2))[0])
        assert np.abs(perturbed - base).max() > 1e-6, (
            f"sequence {b} had no effect on the update (dropped?)"
        )


# ----------------------------------------------------------- learning


@pytest.mark.parametrize("make", [make_rec_ippo, make_rec_mappo],
                         ids=["rec_ippo", "rec_mappo"])
def test_recurrent_ppo_improves_matrix_game(make):
    """The recurrent PPO variants learn (reward climbs over updates)."""
    system = make(
        MatrixGame(horizon=10),
        PPOConfig(rollout_len=16, epochs=4, num_minibatches=2,
                  entropy_coef=0.02, learning_rate=1e-3, hidden_sizes=(32, 32)),
    )
    _, metrics = train_anakin(system, jax.random.key(0), 50 * 16, num_envs=8)
    r = np.asarray(metrics["reward"]).reshape(50, 16).mean(axis=-1)
    assert r[-10:].mean() > r[:10].mean() + 1.0, (r[:10].mean(), r[-10:].mean())
