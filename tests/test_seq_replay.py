"""Sequence-replay invariants (R2D2 stored-carry windows), property-based.

Four layers of pins:

* the `SeqBufferState` window mechanics against a python oracle —
  striding, overlap, FIFO overwrite, time-order inside each window;
* the schedule invariant — buffer fill is a pure function of the step
  counter (`seq_expected_size` is the closed form), never of the data,
  so the seed-vmap runner's hoisted update gate stays sound;
* the recurrent window semantics — stored-state window starts diverge
  from the retired zero-start approximation, and `burn_in_carry` warms
  memory without leaking TD gradients into the prefix;
* a slow rec-MADQN learning smoke on the climbing matrix game.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buffer import (
    seq_add,
    seq_can_sample,
    seq_expected_size,
    seq_init,
    seq_sample,
)
from repro.core.system import train_anakin
from repro.envs import MatrixGame
from repro.nn.recurrent import ScannedRNN, burn_in_carry, window_start_carry
from repro.systems.rec_madqn import RecMadqnConfig, make_rec_madqn


def _step_items(step, num_envs):
    """Distinguishable payload: value = step * 1000 + env index."""
    return {"x": jnp.arange(num_envs, dtype=jnp.int32) + 1000 * step}


def _oracle_windows(n_steps, window_len, num_envs, stride):
    """Python reference: the window stream `seq_add` should flush, in order."""
    out = []
    for t1 in range(1, n_steps + 1):
        if t1 >= window_len and (t1 - window_len) % stride == 0:
            for e in range(num_envs):
                out.append(
                    [1000 * s + e for s in range(t1 - window_len, t1)]
                )
    return out


# ----------------------------------------------------- window mechanics


@settings(max_examples=40, deadline=None)
@given(
    capacity=st.integers(1, 12),
    window_len=st.integers(1, 6),
    num_envs=st.integers(1, 3),
    stride=st.integers(1, 6),
    n_steps=st.integers(0, 24),
)
def test_seq_windows_match_python_oracle(
    capacity, window_len, num_envs, stride, n_steps
):
    """Striding, overlap, and FIFO overwrite against a python reference.

    The stored table must hold exactly the last ``capacity`` windows of
    the oracle stream, each in time order, with ``size``/``insert_pos``
    tracking the flush count.
    """
    state = seq_init({"x": jnp.zeros((), jnp.int32)}, capacity, window_len, num_envs)
    for step in range(n_steps):
        state = seq_add(state, _step_items(step, num_envs), stride=stride)
    oracle = _oracle_windows(n_steps, window_len, num_envs, stride)

    assert int(state.t) == n_steps
    assert int(state.size) == min(len(oracle), capacity)
    assert int(state.insert_pos) == len(oracle) % capacity

    # FIFO: the last `capacity` oracle windows survive, at ring positions
    stored = np.asarray(state.storage["x"])  # (capacity, window_len)
    survivors = oracle[-capacity:]
    start = (len(oracle) - len(survivors)) % capacity
    for j, win in enumerate(survivors):
        slot = (start + j) % capacity
        assert stored[slot].tolist() == win, (slot, stored[slot], win)


@settings(max_examples=20, deadline=None)
@given(
    window_len=st.integers(2, 6),
    num_envs=st.integers(1, 3),
    stride=st.integers(1, 6),
    batch=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_seq_sample_returns_whole_stored_windows(
    window_len, num_envs, stride, batch, seed
):
    """Samples are whole stored windows, time-major (T, B, ...): each
    sampled column is time-contiguous and appears in the oracle stream."""
    capacity, n_steps = 16, 20
    state = seq_init({"x": jnp.zeros((), jnp.int32)}, capacity, window_len, num_envs)
    for step in range(n_steps):
        state = seq_add(state, _step_items(step, num_envs), stride=stride)
    oracle = {tuple(w) for w in _oracle_windows(n_steps, window_len, num_envs, stride)}
    if not oracle:
        return
    out = np.asarray(seq_sample(state, jax.random.key(seed), batch)["x"])
    assert out.shape == (window_len, batch)
    for b in range(batch):
        col = tuple(out[:, b].tolist())
        assert col in oracle, col
        # time-contiguous: consecutive rows are consecutive steps
        assert all(col[j + 1] - col[j] == 1000 for j in range(window_len - 1))


# ------------------------------------------- the schedule invariant


@settings(max_examples=25, deadline=None)
@given(
    capacity=st.integers(1, 12),
    window_len=st.integers(1, 5),
    num_envs=st.integers(1, 3),
    stride=st.integers(1, 5),
    n_steps=st.integers(0, 24),
    data_seed=st.integers(0, 2**31 - 1),
)
def test_fill_is_pure_function_of_step_counter(
    capacity, window_len, num_envs, stride, n_steps, data_seed
):
    """Regression pin: buffer fill never keys on the *data*.

    ``size`` must equal the `seq_expected_size` closed form after every
    single step — for an arbitrary random data stream — and the whole
    can-sample trace must be identical across different data streams.
    This is the invariant that keeps the seed-vmap runner's hoisted
    update gate (`_one_iteration_seeds`) data-independent; a
    fill-triggered prioritization scheme would trip it immediately.
    """
    def run(key):
        state = seq_init({"x": jnp.zeros(())}, capacity, window_len, num_envs)
        sizes, gates = [], []
        for step in range(n_steps):
            key, k = jax.random.split(key)
            items = {"x": jax.random.normal(k, (num_envs,))}
            state = seq_add(state, items, stride=stride)
            sizes.append(int(state.size))
            gates.append(bool(seq_can_sample(state, num_envs)))
        return sizes, gates

    sizes, gates = run(jax.random.key(data_seed))
    for step, size in enumerate(sizes):
        assert size == seq_expected_size(
            step + 1, capacity, window_len, num_envs, stride
        ), (step, size)
    sizes2, gates2 = run(jax.random.key(data_seed + 1))
    assert sizes == sizes2 and gates == gates2


def test_rec_madqn_update_schedule_is_data_independent():
    """Different seeds (different actions, rewards, carries — different
    *data*) must run the identical update schedule: train.steps is a pure
    function of the iteration count."""
    system = make_rec_madqn(
        MatrixGame(horizon=6),
        RecMadqnConfig(hidden_sizes=(8,), seq_len=4, burn_in=2,
                       buffer_capacity=64, batch_size=4, min_windows=4,
                       eps_decay_steps=50, target_update_period=5),
    )
    steps = []
    for seed in (0, 1, 2):
        st_out, _ = train_anakin(system, jax.random.key(seed), 24, num_envs=4)
        steps.append(int(st_out.train.steps))
        assert int(st_out.buffer.size) == seq_expected_size(24, 64, 6, 4, 4)
    assert steps[0] >= 1
    assert steps[0] == steps[1] == steps[2], steps


# --------------------------------------- stored-carry window semantics


def test_window_start_carry_reads_stored_row_zero():
    carry_in = jnp.arange(12.0).reshape(3, 2, 2)  # (T, B, hidden)
    got = window_start_carry(
        {"carry_in": carry_in}, lambda bs: jnp.zeros((*bs, 2)), (2,)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(carry_in[0]))


def test_stored_carry_start_diverges_from_zero_start():
    """The tentpole semantics: on a window whose stored row-0 carry is
    nonzero (mid-episode cut), training from the stored state produces
    different activations than the retired zero-start fallback — and the
    stored path is exactly an unroll from the stored carry."""
    core = ScannedRNN(in_dim=3, hidden_dim=4)
    params = core.init(jax.random.key(0))
    T, B = 5, 2
    xs = jax.random.normal(jax.random.key(1), (T, B, 3))
    stored = jax.random.normal(jax.random.key(2), (T, B, 4))  # per-step carry_in

    c_stored = window_start_carry(
        {"carry_in": stored}, core.initial_carry, (B,)
    )
    c_zero = window_start_carry({}, core.initial_carry, (B,))
    np.testing.assert_array_equal(np.asarray(c_zero), np.zeros((B, 4)))

    _, out_stored = core.unroll(params, c_stored, xs)
    _, out_zero = core.unroll(params, c_zero, xs)
    assert np.abs(np.asarray(out_stored) - np.asarray(out_zero)).max() > 1e-4
    _, ref = core.unroll(params, stored[0], xs)
    np.testing.assert_array_equal(np.asarray(out_stored), np.asarray(ref))


def test_burn_in_carry_warms_exactly_and_stops_gradients():
    """`burn_in_carry` == the direct prefix unroll numerically, but TD
    gradients must not flow through it: a loss on the warmed carry has
    zero gradient wrt params and the window-start carry, while the same
    loss on the un-stopped unroll does not."""
    core = ScannedRNN(in_dim=3, hidden_dim=4)
    params = core.init(jax.random.key(0))
    Tb, B = 3, 2
    xs = jax.random.normal(jax.random.key(1), (Tb, B, 3))
    resets = jnp.zeros((Tb, B), bool)
    c0 = jax.random.normal(jax.random.key(2), (B, 4))
    unroll = lambda c, x, r: core.unroll(params, c, x, r)

    warmed = burn_in_carry(unroll, c0, xs, resets)
    direct, _ = core.unroll(params, c0, xs, resets)
    np.testing.assert_array_equal(np.asarray(warmed), np.asarray(direct))

    def loss_through_burn_in(params, c0):
        u = lambda c, x, r: core.unroll(params, c, x, r)
        return jnp.sum(burn_in_carry(u, c0, xs, resets) ** 2)

    def loss_unstopped(params, c0):
        carry, _ = core.unroll(params, c0, xs, resets)
        return jnp.sum(carry ** 2)

    gp, gc = jax.grad(loss_through_burn_in, argnums=(0, 1))(params, c0)
    for leaf in jax.tree_util.tree_leaves((gp, gc)):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)
    gp_ref, gc_ref = jax.grad(loss_unstopped, argnums=(0, 1))(params, c0)
    assert max(
        np.abs(np.asarray(leaf)).max()
        for leaf in jax.tree_util.tree_leaves((gp_ref, gc_ref))
    ) > 1e-6  # the stop is what zeroed them, not a degenerate loss


def test_burn_in_carry_zero_length_prefix_passes_carry_through():
    core = ScannedRNN(in_dim=3, hidden_dim=4)
    params = core.init(jax.random.key(0))
    c0 = jax.random.normal(jax.random.key(1), (2, 4))
    xs = jnp.zeros((0, 2, 3))
    unroll = lambda c, x, r: core.unroll(params, c, x, r)
    out = burn_in_carry(unroll, c0, xs, jnp.zeros((0, 2), bool))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(c0))
    g = jax.grad(
        lambda c: jnp.sum(burn_in_carry(unroll, c, xs, jnp.zeros((0, 2), bool)))
    )(c0)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_rec_madqn_rejects_bad_window_config():
    env = MatrixGame(horizon=6)
    with pytest.raises(ValueError):
        make_rec_madqn(env, RecMadqnConfig(seq_len=0))
    with pytest.raises(ValueError):
        make_rec_madqn(env, RecMadqnConfig(burn_in=-1))
    with pytest.raises(ValueError):
        make_rec_madqn(env, RecMadqnConfig(stride=0))


# ----------------------------------------------------------- learning


@pytest.mark.slow
def test_rec_madqn_improves_matrix_game():
    """rec-MADQN learns on the climbing game (reward climbs over updates)."""
    system = make_rec_madqn(
        MatrixGame(horizon=10),
        RecMadqnConfig(hidden_sizes=(32,), learning_rate=1e-3,
                       seq_len=5, burn_in=2, buffer_capacity=1024,
                       batch_size=32, min_windows=64,
                       eps_decay_steps=3000, target_update_period=100),
    )
    _, metrics = train_anakin(system, jax.random.key(0), 5000, num_envs=8)
    r = np.asarray(metrics["reward"]).reshape(100, 50).mean(axis=-1)
    assert r[-10:].mean() > r[:10].mean() + 1.0, (r[:10].mean(), r[-10:].mean())
