"""RWARE-lite and Level-Based Foraging mechanics (raw-env unit tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs.api import StepType
from repro.envs.grid import apply_moves, resolve_collisions
from repro.envs.lbf import LbfState, LevelBasedForaging
from repro.envs.robot_warehouse import RobotWarehouse, RwareState


def acts(env, values):
    return {
        a: jnp.asarray(v, jnp.int32) for a, v in zip(env.agent_ids, values)
    }


# ------------------------------------------------------------ shared grid


def test_apply_moves_clips_to_grid():
    pos = jnp.array([[0, 0], [4, 4]], jnp.int32)
    out = apply_moves(pos, jnp.array([1, 2]), 5)  # up at top, down at bottom
    np.testing.assert_array_equal(np.asarray(out), [[0, 0], [4, 4]])


def test_resolve_collisions_contested_cell():
    # both agents propose (1, 1): both stay put
    pos = jnp.array([[1, 0], [1, 2]], jnp.int32)
    proposed = jnp.array([[1, 1], [1, 1]], jnp.int32)
    out = resolve_collisions(pos, proposed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(pos))


def test_resolve_collisions_swap_blocked():
    pos = jnp.array([[0, 0], [0, 1]], jnp.int32)
    proposed = jnp.array([[0, 1], [0, 0]], jnp.int32)  # swap
    out = resolve_collisions(pos, proposed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(pos))


def test_resolve_collisions_free_move_passes():
    pos = jnp.array([[0, 0], [3, 3]], jnp.int32)
    proposed = jnp.array([[0, 1], [3, 2]], jnp.int32)
    out = resolve_collisions(pos, proposed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(proposed))


# ----------------------------------------------------------------- rware


def _rware():
    return RobotWarehouse(num_agents=2, grid_size=8, num_shelves=4, num_requests=2)


def _rware_state(env, pos, carrying, requested):
    return RwareState(
        t=jnp.zeros((), jnp.int32),
        pos=jnp.asarray(pos, jnp.int32),
        carrying=jnp.asarray(carrying, jnp.int32),
        requested=jnp.asarray(requested, bool),
        key=jax.random.key(0),
    )


def test_rware_load_picks_requested_shelf():
    env = _rware()
    shelf0 = tuple(int(x) for x in env.shelf_pos[0])
    state = _rware_state(env, [shelf0, (0, 0)], [-1, -1], [True, True, False, False])
    state, ts = env.step(state, acts(env, [5, 0]))  # agent_0 loads shelf 0
    assert int(state.carrying[0]) == 0
    assert int(state.carrying[1]) == -1
    assert float(ts.reward["agent_0"]) == 0.0  # pickup alone pays nothing


def test_rware_delivery_pays_team_and_resamples_request():
    env = _rware()
    goal = tuple(int(x) for x in env.goal_pos)
    above = (goal[0] - 1, goal[1])
    state = _rware_state(env, [above, (0, 0)], [1, -1], [True, True, False, False])
    state, ts = env.step(state, acts(env, [2, 0]))  # move down onto the goal
    assert tuple(int(x) for x in state.pos[0]) == goal
    # sparse shared +1 for the whole team
    assert float(ts.reward["agent_0"]) == 1.0
    assert float(ts.reward["agent_1"]) == 1.0
    # delivered shelf unloaded; a fresh request keeps num_requests outstanding
    assert int(state.carrying[0]) == -1
    assert int(state.requested.sum()) == env.num_requests


def test_rware_loaded_robot_blocked_by_occupied_rack():
    env = _rware()
    shelf0 = tuple(int(x) for x in env.shelf_pos[0])
    left = (shelf0[0], shelf0[1] - 1)
    # agent_0 is loaded with shelf 1 and tries to move right under shelf 0
    state = _rware_state(env, [left, (0, 0)], [1, -1], [True, True, False, False])
    state, _ = env.step(state, acts(env, [4, 0]))
    assert tuple(int(x) for x in state.pos[0]) == left  # blocked
    # unloaded robots pass under racks freely
    state = _rware_state(env, [left, (0, 0)], [-1, -1], [True, True, False, False])
    state, _ = env.step(state, acts(env, [4, 0]))
    assert tuple(int(x) for x in state.pos[0]) == shelf0


def test_rware_episode_ends_on_horizon_only():
    env = RobotWarehouse(num_agents=2, grid_size=6, num_shelves=4, horizon=5)
    state, ts = env.reset(jax.random.key(0))
    for t in range(1, 6):
        state, ts = env.step(state, acts(env, [0, 0]))
        expected = StepType.LAST if t == 5 else StepType.MID
        assert int(ts.step_type) == expected


# ------------------------------------------------------------------- lbf


def _lbf(**kw):
    kw.setdefault("num_agents", 2)
    kw.setdefault("grid_size", 6)
    kw.setdefault("num_food", 2)
    return LevelBasedForaging(**kw)


def _lbf_state(pos, levels, food_pos, food_level, food_active):
    return LbfState(
        t=jnp.zeros((), jnp.int32),
        pos=jnp.asarray(pos, jnp.int32),
        levels=jnp.asarray(levels, jnp.int32),
        food_pos=jnp.asarray(food_pos, jnp.int32),
        food_level=jnp.asarray(food_level, jnp.int32),
        food_active=jnp.asarray(food_active, bool),
    )


def test_lbf_lone_agent_cannot_collect_high_food():
    env = _lbf()
    # food 0 (level 3) adjacent to agent 0 (level 1): loading alone fails
    state = _lbf_state([(2, 1), (5, 5)], [1, 2], [(2, 2), (0, 0)], [3, 1], [True, True])
    state, ts = env.step(state, acts(env, [5, 0]))
    assert bool(state.food_active[0])
    assert float(ts.reward["agent_0"]) == 0.0


def test_lbf_pooled_levels_collect_and_split_by_level():
    env = _lbf()
    # both agents adjacent to food 0 (level 3); levels 1 + 2 >= 3
    state = _lbf_state([(2, 1), (2, 3)], [1, 2], [(2, 2), (0, 0)], [3, 1], [True, True])
    state, ts = env.step(state, acts(env, [5, 5]))
    assert not bool(state.food_active[0])
    total = 3 + 1  # normaliser: total food level
    assert float(ts.reward["agent_0"]) == pytest.approx(3 * (1 / 3) / total)
    assert float(ts.reward["agent_1"]) == pytest.approx(3 * (2 / 3) / total)


def test_lbf_shared_reward_regime_pays_team_mean():
    env = _lbf(shared_reward=True)
    state = _lbf_state([(2, 1), (2, 3)], [1, 2], [(2, 2), (0, 0)], [3, 1], [True, True])
    _, ts = env.step(state, acts(env, [5, 5]))
    r0, r1 = float(ts.reward["agent_0"]), float(ts.reward["agent_1"])
    assert r0 == r1 == pytest.approx((3 / 4) / 2)  # mean of the per-agent split


def test_lbf_food_cells_are_solid():
    env = _lbf()
    state = _lbf_state([(2, 1), (5, 5)], [1, 1], [(2, 2), (0, 0)], [1, 1], [True, True])
    state, _ = env.step(state, acts(env, [4, 0]))  # move right into the food
    assert tuple(int(x) for x in state.pos[0]) == (2, 1)
    # once collected, the cell opens up
    state = _lbf_state([(2, 1), (5, 5)], [1, 1], [(2, 2), (0, 0)], [1, 1], [False, True])
    state, _ = env.step(state, acts(env, [4, 0]))
    assert tuple(int(x) for x in state.pos[0]) == (2, 2)


def test_lbf_all_food_collected_terminates_early():
    env = _lbf()
    # one active level-1 food left, adjacent loader collects -> LAST
    state = _lbf_state([(2, 1), (5, 5)], [1, 1], [(2, 2), (0, 0)], [1, 1], [True, False])
    state, ts = env.step(state, acts(env, [5, 0]))
    assert int(ts.step_type) == StepType.LAST
    assert float(ts.discount) == 0.0


def test_lbf_reward_regimes_same_team_total():
    """Per-agent and shared regimes redistribute, not rescale, reward."""
    for shared in (False, True):
        env = _lbf(shared_reward=shared)
        state = _lbf_state(
            [(2, 1), (2, 3)], [2, 1], [(2, 2), (0, 0)], [3, 2], [True, True]
        )
        _, ts = env.step(state, acts(env, [5, 5]))
        total = sum(float(r) for r in ts.reward.values())
        assert total == pytest.approx(3 / 5)
