"""Communication / stabilisation / architecture module tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.architectures import (
    CentralisedQValueCritic,
    DecentralisedPolicyActor,
    NetworkedQValueCritic,
)
from repro.core.modules.communication import BroadcastedCommunication, dru
from repro.core.modules.stabilisation import FingerPrintStabilisation


def test_dru_train_vs_exec():
    m = jnp.asarray([-2.0, 0.5, 3.0])
    hard = dru(m, jax.random.key(0), 0.5, training=False)
    np.testing.assert_array_equal(np.asarray(hard), [0.0, 1.0, 1.0])
    soft = dru(m, jax.random.key(0), 0.5, training=True)
    assert ((np.asarray(soft) > 0) & (np.asarray(soft) < 1)).all()


def test_dru_training_is_differentiable():
    g = jax.grad(lambda m: dru(m, jax.random.key(0), 0.5, True).sum())(
        jnp.asarray([0.3])
    )
    assert float(jnp.abs(g[0])) > 0.0


def test_broadcast_routing_excludes_self():
    comm = BroadcastedCommunication(channel_size=1, shared=True)
    msgs = {f"agent_{i}": jnp.full((1,), float(i)) for i in range(3)}
    inc = comm.route(msgs)
    # agent_0 hears mean of 1 and 2
    np.testing.assert_allclose(np.asarray(inc["agent_0"]), [1.5])
    np.testing.assert_allclose(np.asarray(inc["agent_2"]), [0.5])


def test_fingerprint_appends_two_dims():
    fp = FingerPrintStabilisation()
    obs = {"a": jnp.zeros((5, 3))}
    out = fp.augment(obs, eps=0.3, step=jnp.asarray(100))
    assert out["a"].shape == (5, 5)
    np.testing.assert_allclose(np.asarray(out["a"][0, 3:]), [0.3, 0.01])


def _setup_arch_inputs():
    obs = {"agent_0": jnp.ones((4,)), "agent_1": 2 * jnp.ones((4,))}
    acts = {"agent_0": jnp.asarray([1.0, 0.0]), "agent_1": jnp.asarray([0.0, 1.0])}
    gs = jnp.arange(6, dtype=jnp.float32)
    return obs, acts, gs


def test_decentralised_critic_sees_own_only():
    arch = DecentralisedPolicyActor()
    obs, acts, gs = _setup_arch_inputs()
    out = arch.critic_input(obs, acts, gs, "agent_0")
    assert out.shape == (6,)  # own obs(4) + own act(2)


def test_centralised_critic_sees_state_and_all_actions():
    arch = CentralisedQValueCritic(agent_order=("agent_0", "agent_1"))
    obs, acts, gs = _setup_arch_inputs()
    out = arch.critic_input(obs, acts, gs, "agent_0")
    assert out.shape == (6 + 4,)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1))
def test_networked_critic_masks_non_neighbours(i):
    adj = ((1, 0), (1, 1))  # agent_0 sees only itself; agent_1 sees both
    arch = NetworkedQValueCritic(adjacency=adj, agent_order=("agent_0", "agent_1"))
    obs, acts, gs = _setup_arch_inputs()
    out0 = arch.critic_input(obs, acts, gs, "agent_0")
    # agent_1's features are zero-masked for agent_0
    np.testing.assert_allclose(np.asarray(out0[6:]), 0.0)
    out1 = arch.critic_input(obs, acts, gs, "agent_1")
    assert np.abs(np.asarray(out1)).sum() > np.abs(np.asarray(out0)).sum()


def _dial_per_update_rewards(protocol: str, num_updates: int):
    """Train DIAL/RIAL via the unified Anakin runner; per-update rewards."""
    from repro.core.system import train_anakin
    from repro.envs import SwitchGame
    from repro.systems.dial import DialConfig, make_dial

    env = SwitchGame(num_agents=3)
    system = make_dial(env, DialConfig(protocol=protocol))
    rollout_len = env.horizon  # DialConfig default: one episode per env
    _, metrics = train_anakin(
        system, jax.random.key(0), num_updates * rollout_len, num_envs=16
    )
    r = np.asarray(metrics["reward"])
    return r.reshape(num_updates, rollout_len).mean(axis=-1)


def test_dial_learns_on_switch_game_smoke():
    """Short DIAL run through the unified System runner: not diverging."""
    r = _dial_per_update_rewards("dial", 60)
    assert np.isfinite(r).all()
    assert r[-15:].mean() > r[:15].mean() - 0.05  # not diverging


def test_rial_protocol_learns():
    """RIAL (discrete Q-learned channel) must also improve on the riddle."""
    r = _dial_per_update_rewards("rial", 120)
    assert np.isfinite(r).all()
    assert r[-30:].mean() > r[:30].mean()
