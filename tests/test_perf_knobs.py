"""§Perf optimization knobs must preserve numerics (see EXPERIMENTS.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import model as M


def _batch(cfg, B=2, S=48, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }


def _loss(cfg, params, batch):
    loss, _ = jax.jit(lambda p, b: M.forward_train(p, b, cfg))(params, batch)
    return float(loss)


def test_causal_skip_matches_scanned_attention():
    cfg0 = get_smoke_config("granite-8b")
    cfg1 = dataclasses.replace(cfg0, attn_causal_skip=True)
    params = M.init_model(jax.random.key(0), cfg0)
    batch = _batch(cfg0)
    assert abs(_loss(cfg0, params, batch) - _loss(cfg1, params, batch)) < 1e-4


@pytest.mark.parametrize("k", [2, 4])
def test_grad_accum_matches_full_batch(k):
    cfg0 = get_smoke_config("internlm2-1.8b")
    cfgk = dataclasses.replace(cfg0, grad_accum=k)
    params = M.init_model(jax.random.key(0), cfg0)
    batch = _batch(cfg0, B=4)
    opt0, step0 = make_train_step(cfg0, 1e-3)
    optk, stepk = make_train_step(cfgk, 1e-3)
    p0, _, m0 = jax.jit(step0)(params, opt0.init(params), batch)
    pk, _, mk = jax.jit(stepk)(params, optk.init(params), batch)
    # microbatch loss mean == full-batch loss (uniform token counts)
    assert abs(float(m0["loss"]) - float(mk["loss"])) < 1e-4
    a = np.asarray(jax.tree_util.tree_leaves(p0)[0], np.float32)
    b = np.asarray(jax.tree_util.tree_leaves(pk)[0], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-2)  # fp32-accum vs single grad


def test_save_layer_outputs_policy_matches():
    cfg0 = get_smoke_config("olmoe-1b-7b")
    cfg1 = dataclasses.replace(cfg0, save_layer_outputs=True)
    params = M.init_model(jax.random.key(0), cfg0)
    batch = _batch(cfg0)
    opt, step0 = make_train_step(cfg0, 1e-3)
    _, step1 = make_train_step(cfg1, 1e-3)
    s = opt.init(params)
    _, _, m0 = jax.jit(step0)(params, s, batch)
    _, _, m1 = jax.jit(step1)(params, s, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4


def test_sequence_sharding_rules_are_inert_without_mesh():
    """fsdp_tp_sp model code must run unsharded (constraints no-op)."""
    from repro.distributed.sharding import set_active_rules

    cfg = dataclasses.replace(get_smoke_config("granite-8b"), sharding="fsdp_tp_sp")
    params = M.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg)
    with set_active_rules("fsdp_tp_sp"):
        loss = _loss(cfg, params, batch)
    assert np.isfinite(loss)


def test_flops_param_count_counts_shared_blocks_per_invocation():
    from repro.configs import get_config

    z = get_config("zamba2-2.7b")
    assert z.flops_param_count() > z.active_param_count()
    d = get_config("granite-8b")
    assert d.flops_param_count() == d.active_param_count()


@pytest.mark.parametrize("arch", ["granite-8b", "falcon-mamba-7b"])
def test_use_pallas_training_matches_jnp_path(arch):
    """cfg.use_pallas swaps in the Pallas kernels (interpret on CPU) with a
    custom_vjp oracle backward — one train step must match the jnp path."""
    cfg0 = get_smoke_config(arch)
    cfg1 = dataclasses.replace(cfg0, use_pallas=True)
    params = M.init_model(jax.random.key(0), cfg0)
    batch = _batch(cfg0, S=32)
    opt, step0 = make_train_step(cfg0, 1e-3)
    _, step1 = make_train_step(cfg1, 1e-3)
    s = opt.init(params)
    _, _, m0 = jax.jit(step0)(params, s, batch)
    _, _, m1 = jax.jit(step1)(params, s, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 5e-4
