"""Pallas kernel sweeps: interpret-mode vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_xent.ops import fused_softmax_xent
from repro.kernels.fused_xent.ref import softmax_xent_ref
from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,hd,window,dtype",
    [
        (2, 4, 2, 256, 64, 0, jnp.float32),
        (1, 4, 4, 128, 32, 0, jnp.float32),
        (2, 8, 2, 200, 64, 0, jnp.float32),   # ragged S (padding path)
        (1, 4, 1, 256, 64, 96, jnp.float32),  # sliding window
        (1, 2, 2, 128, 128, 0, jnp.bfloat16),
        (1, 6, 3, 160, 80, 64, jnp.float32),  # zamba-like head_dim=80
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, S, hd, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), dtype)
    out = flash_attention(
        q, k, v, causal=True, window=window, block_q=64, block_kv=64, interpret=True
    )
    ref = attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------- selective scan


@pytest.mark.parametrize(
    "b,S,di,N,block_d,chunk",
    [
        (2, 64, 128, 16, 128, 32),
        (1, 100, 256, 16, 128, 64),  # ragged S
        (2, 32, 64, 8, 64, 32),
        (1, 48, 128, 4, 64, 16),
    ],
)
def test_selective_scan_matches_ref(b, S, di, N, block_d, chunk):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(b, S, di)), jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(size=(b, S, di))) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(di, N))) + 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y, h = selective_scan(
        x, delta, A, B, C, D, block_d=block_d, chunk=chunk, interpret=True
    )
    y_ref, h_ref = selective_scan_ref(x, delta, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4, rtol=1e-4)


def test_selective_scan_matches_model_chunked_scan():
    """The kernel oracle and the model's training-path scan must agree."""
    from repro.models.ssm import selective_scan_chunked

    rng = np.random.default_rng(2)
    b, S, di, N = 2, 64, 32, 8
    x = jnp.asarray(rng.normal(size=(b, S, di)), jnp.float32)
    delta = jnp.asarray(np.abs(rng.normal(size=(b, S, di))) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(di, N))) + 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y_model, _ = selective_scan_chunked(x, delta, A, B, C, D, chunk=16)
    y_ref, _ = selective_scan_ref(x, delta, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref), atol=1e-4)


# ----------------------------------------------------------------- fused xent


@pytest.mark.parametrize(
    "T,d,V,bt,bv",
    [
        (64, 128, 1000, 32, 256),
        (100, 64, 512, 32, 128),   # ragged T
        (128, 32, 2048, 128, 512),
        (32, 16, 77, 32, 64),      # prime-ish vocab (block_v shrink path)
    ],
)
def test_fused_xent_matches_ref(T, d, V, bt, bv):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, V)) * 0.05, jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (T,)), jnp.int32)
    out = fused_softmax_xent(x, w, labels, block_t=bt, block_v=bv, interpret=True)
    ref = softmax_xent_ref(x, w, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_fused_xent_matches_model_chunked_xent():
    from repro.models.layers import chunked_softmax_xent

    rng = np.random.default_rng(3)
    B, S, d, V = 2, 32, 16, 128
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, V)) * 0.05, jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mean_model = chunked_softmax_xent(x, w, labels, chunk=8)
    per_tok = fused_softmax_xent(x.reshape(-1, d), w, labels.reshape(-1), interpret=True)
    np.testing.assert_allclose(float(mean_model), float(per_tok.mean()), atol=1e-5)
