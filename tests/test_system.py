"""End-to-end behaviour of the MARL systems (the paper's core claims)."""
import jax
import numpy as np
import pytest

from repro.core.system import run_environment_loop, train_anakin
from repro.envs import MatrixGame, SwitchGame, Spread
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.qmix import make_qmix
from repro.systems.vdn import make_vdn

FAST_CFG = OffPolicyConfig(
    buffer_capacity=5_000,
    min_replay=100,
    batch_size=32,
    eps_decay_steps=2_000,
    target_update_period=50,
    learning_rate=1e-3,
)


@pytest.mark.parametrize("maker", [make_madqn, make_vdn, make_qmix])
def test_value_system_learns_matrix_game(maker):
    """All value-decomposition systems must beat random on the climbing game."""
    env = MatrixGame(horizon=10)
    system = maker(env, FAST_CFG)
    _, metrics = train_anakin(system, jax.random.key(0), 3_000, num_envs=8)
    r = np.asarray(metrics["reward"])
    early, late = r[:200].mean(), r[-200:].mean()
    assert late > early + 2.0, (early, late)
    assert late > 3.0, late  # random play averages ~ -3.4


def test_faithful_python_loop_runs():
    """The paper's Block-1 environment loop end-to-end (slow path)."""
    env = MatrixGame(horizon=10)
    import dataclasses

    cfg = dataclasses.replace(FAST_CFG, min_replay=20)  # 4 eps x 10 steps
    system = make_madqn(env, cfg)
    train, buffer, ev = run_environment_loop(
        system, jax.random.key(0), num_episodes=4
    )
    assert len(ev.episode_return) == 4
    assert int(train.steps) > 0  # trainer actually updated
    assert np.isfinite(ev.episode_return).all()
    # per-agent returns carry one entry per agent per episode
    assert set(ev.agent_returns) == set(system.spec.agent_ids)
    for r in ev.agent_returns.values():
        assert r.shape == (4,) and np.isfinite(r).all()
    assert (ev.episode_length == env.horizon).all()


def test_anakin_metrics_finite():
    env = Spread(num_agents=3, horizon=25)
    system = make_madqn(env, FAST_CFG)
    st, metrics = train_anakin(system, jax.random.key(1), 50, num_envs=4)
    assert np.isfinite(np.asarray(metrics["reward"])).all()
    # replay buffer got filled
    assert int(st.buffer.size) == 50 * 4


def test_vdn_learns_smax_lite():
    """The paper's Fig-4-bottom setting: VDN improves on the 3-marine battle."""
    from repro.envs import SmaxLite

    env = SmaxLite(num_agents=3)
    cfg = OffPolicyConfig(
        buffer_capacity=50_000,
        min_replay=500,
        batch_size=64,
        eps_decay_steps=4_000,
        target_update_period=200,
        learning_rate=1e-3,
    )
    system = make_vdn(env, cfg)
    _, metrics = train_anakin(system, jax.random.key(0), 8_000, num_envs=8)
    r = np.asarray(metrics["reward"])
    assert r[-800:].mean() > 2.0 * r[:800].mean(), (
        r[:800].mean(),
        r[-800:].mean(),
    )
