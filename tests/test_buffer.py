"""Replay-table invariants (the Reverb replacement), property-based."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.buffer import (
    buffer_add,
    buffer_can_sample,
    buffer_init,
    buffer_sample,
    queue_init,
    queue_pop,
    queue_push,
    queue_size,
    rollout_add,
    rollout_init,
    rollout_ready,
    rollout_reset,
    rollout_take,
)


@settings(max_examples=30, deadline=None)
@given(
    capacity=st.integers(2, 64),
    n_adds=st.integers(1, 8),
    batch=st.integers(1, 16),
)
def test_fifo_overwrite_and_size(capacity, n_adds, batch):
    state = buffer_init({"x": jnp.zeros((), jnp.int32)}, capacity)
    total = 0
    for i in range(n_adds):
        items = {"x": jnp.arange(total, total + batch, dtype=jnp.int32)}
        state = buffer_add(state, items)
        total += batch
    assert int(state.size) == min(total, capacity)
    assert int(state.insert_pos) == total % capacity
    stored = np.asarray(state.storage["x"])
    if total >= capacity:
        # FIFO: exactly the last `capacity` items survive (in ring order)
        expect = set(range(total - capacity, total))
        assert set(stored.tolist()) == expect
    else:
        assert set(stored[: total].tolist()) == set(range(total))


@settings(max_examples=20, deadline=None)
@given(
    capacity=st.integers(4, 32),
    fill=st.integers(1, 40),
    sample=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_uniform_sample_only_from_filled(capacity, fill, sample, seed):
    state = buffer_init({"x": jnp.zeros((), jnp.int32)}, capacity)
    state = buffer_add(state, {"x": jnp.arange(fill, dtype=jnp.int32) + 100})
    out = buffer_sample(state, jax.random.key(seed), sample)
    vals = np.asarray(out["x"])
    live = set(np.asarray(state.storage["x"])[: int(state.size)].tolist())
    assert all(v in live for v in vals.tolist())


def test_can_sample_threshold():
    state = buffer_init({"x": jnp.zeros(())}, 16)
    assert not bool(buffer_can_sample(state, 4))
    state = buffer_add(state, {"x": jnp.zeros((4,))})
    assert bool(buffer_can_sample(state, 4))


def test_pytree_items_roundtrip():
    item = {"obs": {"a": jnp.zeros((3,)), "b": jnp.zeros((2,))}, "r": jnp.zeros(())}
    state = buffer_init(item, 8)
    batch = jax.tree_util.tree_map(
        lambda x: jnp.ones((2,) + x.shape, x.dtype), item
    )
    state = buffer_add(state, batch)
    out = buffer_sample(state, jax.random.key(0), 2)
    assert out["obs"]["a"].shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(out["r"]), np.ones((2,)))


# ------------------------------------------------- rollout accumulator


def _rollout_step(step, num_envs):
    """Distinguishable per-step payload: value = step * 100 + env index."""
    return {"x": jnp.arange(num_envs, dtype=jnp.int32) + 100 * step}


@settings(max_examples=30, deadline=None)
@given(
    rollout_len=st.integers(1, 8),
    num_envs=st.integers(1, 4),
    extra_adds=st.integers(0, 6),
)
def test_rollout_writes_past_len_are_dropped(rollout_len, num_envs, extra_adds):
    """Adds beyond ``rollout_len`` fall off the end: the stored trajectory
    keeps exactly the first ``rollout_len`` steps (JAX out-of-bounds scatter
    drops the rest), while the cursor keeps counting."""
    state = rollout_init({"x": jnp.zeros((), jnp.int32)}, rollout_len, num_envs)
    n_adds = rollout_len + extra_adds
    for step in range(n_adds):
        state = rollout_add(state, _rollout_step(step, num_envs))
    assert int(state.t) == n_adds
    assert bool(rollout_ready(state, rollout_len))
    stored = np.asarray(rollout_take(state)["x"])
    assert stored.shape == (rollout_len, num_envs)
    expect = np.stack(
        [np.arange(num_envs) + 100 * s for s in range(rollout_len)]
    )
    np.testing.assert_array_equal(stored, expect)


@settings(max_examples=30, deadline=None)
@given(
    rollout_len=st.integers(2, 8),
    num_envs=st.integers(1, 4),
    second_fill=st.integers(1, 8),
)
def test_rollout_take_then_reset_overwrites_in_place(
    rollout_len, num_envs, second_fill
):
    """Consume-and-reset rewinds only the cursor; the next pass overwrites
    the prefix in place and the suffix still holds the previous rollout."""
    state = rollout_init({"x": jnp.zeros((), jnp.int32)}, rollout_len, num_envs)
    for step in range(rollout_len):
        state = rollout_add(state, _rollout_step(step, num_envs))
    first = np.asarray(rollout_take(state)["x"]).copy()

    state = rollout_reset(state)
    assert int(state.t) == 0
    assert not bool(rollout_ready(state, rollout_len))
    for step in range(second_fill):
        state = rollout_add(state, _rollout_step(1000 + step, num_envs))
    stored = np.asarray(rollout_take(state)["x"])
    k = min(second_fill, rollout_len)
    expect_new = np.stack(
        [np.arange(num_envs) + 100 * (1000 + s) for s in range(k)]
    )
    np.testing.assert_array_equal(stored[:k], expect_new)
    # untouched tail still shows the consumed rollout — reset is cursor-only
    np.testing.assert_array_equal(stored[k:], first[k:])


# ------------------------------------------------- trajectory queue


@settings(max_examples=30, deadline=None)
@given(
    capacity=st.integers(1, 8),
    n_push=st.integers(0, 20),
    n_pop=st.integers(0, 20),
)
def test_queue_fifo_order_and_drop_incoming(capacity, n_push, n_pop):
    """Pushes past capacity drop the *incoming* item; pops come back in
    exact FIFO order, matching a python deque oracle (wraparound included)."""
    state = queue_init({"x": jnp.zeros((), jnp.int32)}, capacity)
    oracle = []
    for i in range(n_push):
        state, ok = queue_push(state, {"x": jnp.int32(i)})
        assert bool(ok) == (len(oracle) < capacity)
        if bool(ok):
            oracle.append(i)
    assert int(queue_size(state)) == len(oracle)
    for _ in range(min(n_pop, len(oracle))):
        state, item = queue_pop(state)
        assert int(item["x"]) == oracle.pop(0)
    assert int(queue_size(state)) == len(oracle)


@settings(max_examples=20, deadline=None)
@given(capacity=st.integers(1, 6), rounds=st.integers(1, 5))
def test_queue_wraparound_interleaved(capacity, rounds):
    """Alternating fill/drain cycles exercise head wraparound: order and
    size stay exact across ``rounds`` passes over the ring."""
    state = queue_init({"x": jnp.zeros((), jnp.int32)}, capacity)
    nxt = 0
    oracle = []
    for _ in range(rounds):
        for _ in range(capacity):
            state, ok = queue_push(state, {"x": jnp.int32(nxt)})
            if bool(ok):
                oracle.append(nxt)
            nxt += 1
        # drain all but one so the next round wraps at a shifted head
        while len(oracle) > 1:
            state, item = queue_pop(state)
            assert int(item["x"]) == oracle.pop(0)
    while oracle:
        state, item = queue_pop(state)
        assert int(item["x"]) == oracle.pop(0)
    assert int(queue_size(state)) == 0


def test_queue_pop_empty_leaves_size_zero():
    """Popping empty is non-destructive: size stays 0, head stays put, and
    the returned (stale) item is the zero-initialised slot."""
    state = queue_init({"x": jnp.zeros((), jnp.int32)}, 4)
    state, item = queue_pop(state)
    assert int(queue_size(state)) == 0
    assert int(state.head) == 0
    assert int(item["x"]) == 0
    # still fully usable afterwards
    state, ok = queue_push(state, {"x": jnp.int32(7)})
    assert bool(ok)
    state, item = queue_pop(state)
    assert int(item["x"]) == 7
    assert int(queue_size(state)) == 0
