"""Replay-table invariants (the Reverb replacement), property-based."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.buffer import (
    buffer_add,
    buffer_can_sample,
    buffer_init,
    buffer_sample,
)


@settings(max_examples=30, deadline=None)
@given(
    capacity=st.integers(2, 64),
    n_adds=st.integers(1, 8),
    batch=st.integers(1, 16),
)
def test_fifo_overwrite_and_size(capacity, n_adds, batch):
    state = buffer_init({"x": jnp.zeros((), jnp.int32)}, capacity)
    total = 0
    for i in range(n_adds):
        items = {"x": jnp.arange(total, total + batch, dtype=jnp.int32)}
        state = buffer_add(state, items)
        total += batch
    assert int(state.size) == min(total, capacity)
    assert int(state.insert_pos) == total % capacity
    stored = np.asarray(state.storage["x"])
    if total >= capacity:
        # FIFO: exactly the last `capacity` items survive (in ring order)
        expect = set(range(total - capacity, total))
        assert set(stored.tolist()) == expect
    else:
        assert set(stored[: total].tolist()) == set(range(total))


@settings(max_examples=20, deadline=None)
@given(
    capacity=st.integers(4, 32),
    fill=st.integers(1, 40),
    sample=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_uniform_sample_only_from_filled(capacity, fill, sample, seed):
    state = buffer_init({"x": jnp.zeros((), jnp.int32)}, capacity)
    state = buffer_add(state, {"x": jnp.arange(fill, dtype=jnp.int32) + 100})
    out = buffer_sample(state, jax.random.key(seed), sample)
    vals = np.asarray(out["x"])
    live = set(np.asarray(state.storage["x"])[: int(state.size)].tolist())
    assert all(v in live for v in vals.tolist())


def test_can_sample_threshold():
    state = buffer_init({"x": jnp.zeros(())}, 16)
    assert not bool(buffer_can_sample(state, 4))
    state = buffer_add(state, {"x": jnp.zeros((4,))})
    assert bool(buffer_can_sample(state, 4))


def test_pytree_items_roundtrip():
    item = {"obs": {"a": jnp.zeros((3,)), "b": jnp.zeros((2,))}, "r": jnp.zeros(())}
    state = buffer_init(item, 8)
    batch = jax.tree_util.tree_map(
        lambda x: jnp.ones((2,) + x.shape, x.dtype), item
    )
    state = buffer_add(state, batch)
    out = buffer_sample(state, jax.random.key(0), 2)
    assert out["obs"]["a"].shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(out["r"]), np.ones((2,)))
