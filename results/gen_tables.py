import json, sys

def table(path, out):
    recs = json.load(open(path))
    lines = []
    lines.append("| arch | shape | mesh | peak GiB/dev | HLO GFLOPs/dev | HLO GB/dev | coll GB/dev | compute ms | memory ms | coll ms | dominant | useful |")
    lines.append("|---|---|---|---:|---:|---:|---:|---:|---:|---:|---|---:|")
    for r in recs:
        rr = r["roofline"]
        coll = sum(rr["collectives_per_device"].values())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['bytes_per_device']['peak_est']/2**30:.2f} "
            f"| {rr['hlo_flops_global']/r['chips']/1e9:.1f} "
            f"| {rr['hlo_bytes_global']/r['chips']/1e9:.1f} "
            f"| {coll/1e9:.2f} "
            f"| {rr['compute_s']*1e3:.2f} | {rr['memory_s']*1e3:.2f} | {rr['collective_s']*1e3:.2f} "
            f"| {rr['dominant']} | {rr['useful_ratio']:.2f} |")
    open(out, "w").write("\n".join(lines))
    print(out, len(recs), "rows")

if __name__ == "__main__":
    table(sys.argv[1], sys.argv[2])
