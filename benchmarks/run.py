"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows. Modules:

  speedup              JAX-rewrite 10-100x claim (python loop vs fused jit)
  switch_game          Fig 4 top — DIAL communication on the switch riddle
  value_decomposition  Fig 4 bottom — VDN vs MADQN (+QMIX) on smax-lite 3m
  architectures        Fig 6 — MAD4PG centralised vs decentralised; MPE
  distribution         Fig 6 bottom right — scaling with num_executors
  roofline             assignment §Roofline table from the dry-run JSON
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "speedup",
    "switch_game",
    "value_decomposition",
    "architectures",
    "distribution",
    "roofline",
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true", help="reduced iteration counts")
    p.add_argument("--only", choices=MODULES, default=None)
    args = p.parse_args()

    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["bench"])
        t0 = time.time()
        try:
            rows = mod.bench(fast=args.fast)
        except Exception:
            failed.append(name)
            traceback.print_exc()
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        sys.stdout.flush()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
