"""Paper Fig. 6: MAD4PG/MADDPG across architectures on MPE tasks.

Decentralised vs centralised critics on continuous-action spread, plus the
speaker-listener sanity run (discrete, via MAPPO as the modern stand-in for
the paper's feedforward actor-critic on that task).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.architectures import (
    CentralisedQValueCritic,
    DecentralisedPolicyActor,
)
from repro.core.system import train_anakin
from repro.envs import SpeakerListener, Spread
from repro.systems.maddpg import MaddpgConfig, make_mad4pg, make_maddpg

CFG = MaddpgConfig()  # validated recipe: batch 512, critic_lr 3e-3 (see EXPERIMENTS.md)


def bench(fast: bool = False):
    iters = 800 if fast else 30_000
    n_envs = 8
    rows = []
    env = Spread(num_agents=3, horizon=25, continuous=True)
    runs = [
        ("spread/maddpg_centralised", make_maddpg, None),
        ("spread/mad4pg_centralised", make_mad4pg, None),
        (
            "spread/mad4pg_decentralised",
            make_mad4pg,
            DecentralisedPolicyActor(),
        ),
    ]
    for name, maker, arch in runs:
        system = maker(env, CFG, architecture=arch)
        t0 = time.time()
        st, metrics = train_anakin(system, jax.random.key(0), iters, n_envs)
        jax.block_until_ready(st.train.params)
        dt = time.time() - t0
        r = np.asarray(metrics["reward"])
        k = max(iters // 10, 1)
        rows.append(
            (
                name,
                dt / iters * 1e6,
                f"reward_first10%={r[:k].mean():.3f} last10%={r[-k:].mean():.3f}",
            )
        )

    # speaker-listener with MAPPO (asymmetric agents need per-agent nets),
    # through the same unified Anakin runner as the off-policy systems
    from repro.systems.onpolicy import PPOConfig, make_mappo

    sl = SpeakerListener()
    rollout_len = 64
    ppo = make_mappo(sl, PPOConfig(rollout_len=rollout_len, shared_weights=False))
    updates = 30 if fast else 400
    t0 = time.time()
    st, metrics = train_anakin(ppo, jax.random.key(0), updates * rollout_len, 16)
    jax.block_until_ready(st.train.params)
    dt = time.time() - t0
    r = np.asarray(metrics["reward"])
    k = max(len(r) // 10, 1)
    rows.append(
        (
            "speaker_listener/mappo",
            dt / updates * 1e6,
            f"reward_first10%={r[:k].mean():.3f} last10%={r[-k:].mean():.3f}",
        )
    )
    return rows
