"""JAX-rewrite speedup claim (10-100x): env-steps/sec across execution modes.

Rungs of the same MADQN system on the same environment:
  acme-style   — the paper's Block-1 python loop (one env step + one update
                 per python iteration; jitted fns, python-paced control flow)
  anakin-jit   — whole loop fused into one lax.scan under jit, 1 env
  anakin-vmap  — fused + vmap over N parallel envs
  seed-vmap    — N independent seeds as one vmapped jit program vs N serial
                 calls of the compiled per-seed program (repro.bench)

Reported: environment steps per second and speedup over the python loop.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.bench.throughput import measure_seed_vectorization
from repro.core.system import run_environment_loop, train_anakin
from repro.envs import Spread
from repro.eval import make_evaluator
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig

CFG = OffPolicyConfig(
    buffer_capacity=10_000, min_replay=200, batch_size=32, eps_decay_steps=5_000
)


def bench(fast: bool = False):
    env = Spread(num_agents=3, horizon=25)
    system = make_madqn(env, CFG)
    key = jax.random.key(0)
    rows = []

    # --- faithful python loop (paper Block 1)
    n_eps = 3 if fast else 10
    t0 = time.time()
    run_environment_loop(system, key, num_episodes=n_eps)
    dt = time.time() - t0
    steps_loop = n_eps * env.horizon
    sps_loop = steps_loop / dt
    rows.append(("speedup/acme_python_loop", dt / steps_loop * 1e6, f"{sps_loop:.0f} steps/s"))

    # --- anakin, 1 env
    iters = 300 if fast else 2_000
    train_anakin(system, key, 10, 1)  # warm compile
    t0 = time.time()
    st, _ = train_anakin(system, key, iters, 1)
    jax.block_until_ready(st.train.params)
    dt = time.time() - t0
    sps_1 = iters / dt
    rows.append(
        ("speedup/anakin_jit_1env", dt / iters * 1e6,
         f"{sps_1:.0f} steps/s = {sps_1 / sps_loop:.1f}x python loop")
    )

    # --- anakin, vmapped envs
    for n_envs in (16, 64):
        train_anakin(system, key, 5, n_envs)
        t0 = time.time()
        st, _ = train_anakin(system, key, iters, n_envs)
        jax.block_until_ready(st.train.params)
        dt = time.time() - t0
        sps = iters * n_envs / dt
        rows.append(
            (f"speedup/anakin_vmap_{n_envs}env", dt / iters * 1e6,
             f"{sps:.0f} steps/s = {sps / sps_loop:.1f}x python loop")
        )

    # --- fused greedy evaluator (repro.eval): same fusion story for eval.
    # Baseline is an eval-mode python loop (training=False: no buffer adds,
    # no updates) so the ratio is eval-vs-eval, not eval-vs-training.
    train = st.train
    t0 = time.time()
    run_environment_loop(
        system, key, num_episodes=n_eps, training=False, train_state=train
    )
    sps_eval_loop = n_eps * env.horizon / (time.time() - t0)
    rows.append(
        ("speedup/python_eval_loop", 1e6 / sps_eval_loop,
         f"{sps_eval_loop:.0f} steps/s")
    )

    n_eval_envs = 16 if fast else 64
    n_episodes = n_eval_envs * (2 if fast else 4)
    eval_fn = jax.jit(make_evaluator(system, n_episodes, n_eval_envs))
    jax.block_until_ready(eval_fn(train, key))  # warm compile
    t0 = time.time()
    jax.block_until_ready(eval_fn(train, key))
    dt = time.time() - t0
    eval_steps = n_episodes * env.horizon
    sps_eval = eval_steps / dt
    rows.append(
        (f"speedup/fused_eval_{n_eval_envs}env", dt / eval_steps * 1e6,
         f"{sps_eval:.0f} steps/s = {sps_eval / sps_eval_loop:.1f}x python eval loop")
    )

    # --- vmap over seeds (repro.bench): N runs as one fused jit program
    n_seeds = 4 if fast else 8
    sv_iters = 64 if fast else 512
    sv = measure_seed_vectorization(system, n_seeds, sv_iters, 16)
    rows.append(
        (f"speedup/seed_vmap_{n_seeds}seeds",
         1e6 / sv["vmapped_steps_per_sec"],
         f"{sv['vmapped_steps_per_sec']:.0f} steps/s = "
         f"{sv['speedup']:.1f}x serial per-seed training")
    )
    return rows
