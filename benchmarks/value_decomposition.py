"""Paper Fig. 4 (bottom): SMAC 3v3 marines — VDN vs independent MADQN.

smax-lite stands in for SC2 (offline container); the claim probed is the
same: additive value decomposition outperforms/matches independent learners
on the 3-marine micromanagement battle. QMIX is included for completeness
(the paper notes their QMIX underperformed — ours is reported as measured).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.system import train_anakin
from repro.envs import SmaxLite
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.qmix import make_qmix
from repro.systems.vdn import make_vdn

CFG = OffPolicyConfig(
    buffer_capacity=50_000,
    min_replay=500,
    batch_size=64,
    eps_decay_steps=4_000,
    target_update_period=200,
    learning_rate=1e-3,
)


def bench(fast: bool = False):
    env = SmaxLite(num_agents=3)
    iters = 1_000 if fast else 12_000
    n_envs = 8
    rows = []
    for maker, name in ((make_madqn, "madqn"), (make_vdn, "vdn"), (make_qmix, "qmix")):
        system = maker(env, CFG)
        t0 = time.time()
        st, metrics = train_anakin(system, jax.random.key(0), iters, n_envs)
        jax.block_until_ready(st.train.params)
        dt = time.time() - t0
        r = np.asarray(metrics["reward"])
        k = max(iters // 10, 1)
        rows.append(
            (
                f"smax3m/{name}",
                dt / iters * 1e6,
                f"reward_first10%={r[:k].mean():.4f} last10%={r[-k:].mean():.4f}",
            )
        )
    return rows
