"""Paper Fig. 4 (top): switch riddle — communication (DIAL) vs none.

The paper's claim: adding the communication module to recurrent MADQN lets
the system solve the riddle (evaluation return -> ~1.0 with 3 agents) while
the comm-less ablation plateaus near the tell-immediately baseline.

DIAL runs through the unified System runner (train_anakin) and the fused
greedy evaluator, like every other system.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.system import train_anakin
from repro.envs import SwitchGame
from repro.eval import evaluate
from repro.systems.dial import DialConfig, make_dial


def bench(fast: bool = False):
    env = SwitchGame(num_agents=3)
    updates = 150 if fast else 2_000
    rollout_len = env.horizon  # one episode per env per update
    num_envs = 32
    rows = []
    variants = (
        ("dial", DialConfig(use_comm=True)),
        ("rial", DialConfig(use_comm=True, protocol="rial")),
        ("no_comm", DialConfig(use_comm=False)),
    )
    for name, cfg in variants:
        system = make_dial(env, cfg)
        t0 = time.time()
        st, metrics = train_anakin(
            system, jax.random.key(0), updates * rollout_len, num_envs
        )
        jax.block_until_ready(st.train.params)
        dt = time.time() - t0
        ev = evaluate(
            system, st.train, jax.random.key(99), num_episodes=256, num_envs=64
        )
        ret = float(np.asarray(ev.episode_return).mean())
        r = np.asarray(metrics["reward"]).reshape(updates, rollout_len)
        rows.append(
            (
                f"switch_game/{name}",
                dt / updates * 1e6,
                f"eval_return={ret:.3f} train_last50={r[-50:].mean():.3f}",
            )
        )
    return rows
