"""Paper Fig. 4 (top): switch riddle — communication (DIAL) vs none.

The paper's claim: adding the communication module to recurrent MADQN lets
the system solve the riddle (evaluation return -> ~1.0 with 3 agents) while
the comm-less ablation plateaus near the tell-immediately baseline.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.envs import SwitchGame
from repro.systems.dial import DialConfig, make_dial, train_dial


def bench(fast: bool = False):
    env = SwitchGame(num_agents=3)
    updates = 150 if fast else 2_000
    rows = []
    variants = (
        ("dial", DialConfig(use_comm=True, batch_episodes=32)),
        ("rial", DialConfig(use_comm=True, batch_episodes=32, protocol="rial")),
        ("no_comm", DialConfig(use_comm=False, batch_episodes=32)),
    )
    for name, cfg in variants:
        t0 = time.time()
        train, metrics, system = train_dial(env, cfg, jax.random.key(0), updates)
        dt = time.time() - t0
        ret = float(system["evaluate"](train, jax.random.key(99), batch=256))
        r = np.asarray(metrics["return"])
        rows.append(
            (
                f"switch_game/{name}",
                dt / updates * 1e6,
                f"eval_return={ret:.3f} train_last50={r[-50:].mean():.3f}",
            )
        )
    return rows
