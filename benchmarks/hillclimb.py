import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run the three chosen (arch, shape) pairs through
their hypothesis->change->measure sequences and dump a JSON log.

  PYTHONPATH=src python -m benchmarks.hillclimb [--pair A|B|C] [--json out]

Pairs (chosen from the 40-pair baseline table):
  A llama3-405b/train_4k    worst roofline fraction (787 GiB/dev — does not fit)
  B olmoe-1b-7b/train_4k    most collective-bound (24% of step time)
  C zamba2-2.7b/prefill_32k worst useful-FLOPs ratio (0.14)
"""

import argparse
import json
import sys

from repro.launch.dryrun import dryrun_pair

EXPERIMENTS = {
    "A": [
        ("llama3-405b", "train_4k", "A0-baseline", {}),
        ("llama3-405b", "train_4k", "A1-grad_accum8", {"grad_accum": 8}),
        (
            "llama3-405b",
            "train_4k",
            "A2-ga16+seqshard",
            {"grad_accum": 16, "sharding": "fsdp_tp_sp"},
        ),
        (
            "llama3-405b",
            "train_4k",
            "A3-ga16+sp+xent256",
            {"grad_accum": 16, "sharding": "fsdp_tp_sp", "xent_chunk": 256},
        ),
        (
            "llama3-405b",
            "train_4k",
            "A4-ga32+sp+causal_skip",
            {
                "grad_accum": 32,
                "sharding": "fsdp_tp_sp",
                "attn_causal_skip": True,
            },
        ),
    ],
    "B": [
        ("olmoe-1b-7b", "train_4k", "B0-baseline", {}),
        ("olmoe-1b-7b", "train_4k", "B1-save_layer_outputs", {"save_layer_outputs": True}),
        (
            "olmoe-1b-7b",
            "train_4k",
            "B2-slo+group256",
            {"save_layer_outputs": True, "moe_group_size": 256},
        ),
        (
            "olmoe-1b-7b",
            "train_4k",
            "B3-slo+group256+causal_skip",
            {
                "save_layer_outputs": True,
                "moe_group_size": 256,
                "attn_causal_skip": True,
            },
        ),
    ],
    "C": [
        ("zamba2-2.7b", "prefill_32k", "C0-baseline", {}),
        ("zamba2-2.7b", "prefill_32k", "C1-ssm_chunk64", {"ssm_chunk": 64}),
        (
            "zamba2-2.7b",
            "prefill_32k",
            "C2-chunk64+causal_skip",
            {"ssm_chunk": 64, "attn_causal_skip": True},
        ),
        (
            "zamba2-2.7b",
            "prefill_32k",
            "C3-chunk32+causal_skip",
            {"ssm_chunk": 32, "attn_causal_skip": True},
        ),
    ],
}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--pair", default=None)
    p.add_argument("--json", default="results/hillclimb.json")
    args = p.parse_args()

    pairs = [args.pair] if args.pair else ["A", "B", "C"]
    for pid in pairs:
        if pid not in EXPERIMENTS:
            p.error(f"unknown pair {pid}")
    records = []
    for pid in pairs:
        for arch, shape, label, overrides in EXPERIMENTS[pid]:
            try:
                rec = dryrun_pair(arch, shape, verbose=False, overrides=overrides)
            except Exception as e:  # noqa: BLE001
                print(f"[FAIL] {label}: {type(e).__name__}: {str(e)[:200]}")
                sys.stdout.flush()
                continue
            rec["label"] = label
            rec["overrides"] = overrides
            records.append(rec)
            r = rec["roofline"]
            print(
                f"[{label:28s}] peak/dev={rec['bytes_per_device']['peak_est']/2**30:8.2f}GiB "
                f"compute={r['compute_s']*1e3:9.2f}ms memory={r['memory_s']*1e3:10.2f}ms "
                f"coll={r['collective_s']*1e3:8.2f}ms useful={r['useful_ratio']:.3f} "
                f"(compile {rec['compile_s']}s)"
            )
            sys.stdout.flush()
    os.makedirs(os.path.dirname(args.json), exist_ok=True)
    with open(args.json, "w") as f:
        json.dump(records, f, indent=1)


# Round-2 experiments appended after analysing round 1 (see EXPERIMENTS.md):
EXPERIMENTS["A2"] = [
    ("llama3-405b", "train_4k", "A5-ga32+sp", {"grad_accum": 32, "sharding": "fsdp_tp_sp"}),
]
EXPERIMENTS["C2"] = [
    # code change between rounds: chunk-local fp32 casting + bf16 conv in the
    # SSM paths (ssm.py) — C4 is the new "baseline-config" measurement.
    ("zamba2-2.7b", "prefill_32k", "C4-chunklocal-cast", {}),
    ("zamba2-2.7b", "prefill_32k", "C5-cast+chunk256", {"ssm_chunk": 256}),
    ("falcon-mamba-7b", "train_4k", "C6-falcon-cast-check", {}),
]


EXPERIMENTS["A3"] = [
    ("llama3-405b", "train_4k", "A6-ga8+sp", {"grad_accum": 8, "sharding": "fsdp_tp_sp"}),
    ("llama3-405b", "train_4k", "A7-ga4+sp", {"grad_accum": 4, "sharding": "fsdp_tp_sp"}),
]

EXPERIMENTS["A4"] = [
    ("llama3-405b", "train_4k", "A8-ga2+sp", {"grad_accum": 2, "sharding": "fsdp_tp_sp"}),
    ("llama3-405b", "train_4k", "A9-ga1+sp", {"grad_accum": 1, "sharding": "fsdp_tp_sp"}),
]


EXPERIMENTS["D"] = [
    # Pair D (round 3): decode_32k KV caches exceed HBM when n_kv < model
    # axis (kv heads unshardable). Flash-decoding-style cache sharding:
    # shard the cache seq dim over "model"; softmax combines via small ARs.
    ("llama3-405b", "decode_32k", "D0-baseline", {}),
    ("llama3-405b", "decode_32k", "D1-shard_kv_seq", {"shard_kv_seq": True}),
    ("minitron-8b", "decode_32k", "D2-minitron-baseline", {}),
    ("minitron-8b", "decode_32k", "D3-minitron-kv_seq", {"shard_kv_seq": True}),
]


if __name__ == "__main__":
    main()
