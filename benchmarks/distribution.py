"""Paper Fig. 6 (bottom right): time-to-reward vs number of executors.

The paper scales Launchpad executor processes; here the executors are
devices on the mesh data axis (shard_map). On this container the devices
are host-platform CPU slices, so wall-clock does not improve — the claim
probed is *system* scaling: reward-per-env-step parity while total
throughput (env-steps/sec summed over executors) rises with executor count.
Runs in a subprocess because jax fixes the device count at first init.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import time, jax, numpy as np
from repro.envs import Spread
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig
from repro.core.system import train_distributed, train_anakin
from repro.launch.mesh import make_auto_mesh

iters = {iters}
for n_exec in (1, 2, 4):
    env = Spread(num_agents=3, horizon=25)
    cfg = OffPolicyConfig(buffer_capacity=20000, min_replay=500, batch_size=64,
                          eps_decay_steps=10000,
                          distributed_axis="data" if n_exec > 1 else None)
    system = make_madqn(env, cfg)
    key = jax.random.key(0)
    t0 = time.time()
    if n_exec == 1:
        st, metrics = train_anakin(system, key, iters, 8)
        jax.block_until_ready(st.train.params)
        r = float(np.asarray(metrics["reward"])[-iters//10:].mean())
    else:
        mesh = make_auto_mesh((n_exec,), ("data",))
        params, metrics = train_distributed(system, key, iters, 8, mesh)
        r = float(np.asarray(metrics["reward"]).mean())
    dt = time.time() - t0
    steps = iters * 8 * n_exec
    print(f"ROW,distribution/num_executors_{{n_exec}},{{dt/iters*1e6:.1f}},"
          f"reward={{r:.3f}} total_env_steps/s={{steps/dt:.0f}} wall={{dt:.1f}}s")
"""


def bench(fast: bool = False):
    iters = 400 if fast else 4_000
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CODE.format(iters=iters))],
        capture_output=True,
        text=True,
        env=env,
        timeout=3000,
    )
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    if not rows:
        rows.append(("distribution/FAILED", 0.0, (r.stderr or r.stdout)[-200:]))
    return rows
