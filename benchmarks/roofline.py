"""Roofline table: formats the dry-run JSON into the §Roofline report.

Reads results/dryrun_baseline.json (produced by
`python -m repro.launch.dryrun --all --json ...`); if absent, runs a reduced
in-process subset via subprocess (512 fake devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
BASELINE = os.path.join(ROOT, "results", "dryrun_baseline.json")

_SUBSET = [
    ("internlm2-1.8b", "train_4k"),
    ("olmoe-1b-7b", "train_4k"),
    ("falcon-mamba-7b", "decode_32k"),
]


def _ensure_records(fast: bool):
    if os.path.exists(BASELINE):
        with open(BASELINE) as f:
            return json.load(f)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    records = []
    for arch, shape in _SUBSET[: 1 if fast else 3]:
        out = os.path.join(ROOT, "results", f"_roofline_{arch}_{shape}.json")
        os.makedirs(os.path.dirname(out), exist_ok=True)
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--json", out],
            env=env, cwd=ROOT, timeout=1200, capture_output=True,
        )
        if os.path.exists(out):
            records.extend(json.load(open(out)))
    return records


def bench(fast: bool = False):
    records = _ensure_records(fast)
    rows = []
    for rec in records:
        r = rec["roofline"]
        dom_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
        rows.append(
            (
                f"roofline/{rec['arch']}/{rec['shape']}",
                dom_ms * 1e3,  # us per step at the dominant-term bound
                f"dom={r['dominant']} compute={r['compute_s']*1e3:.2f}ms "
                f"mem={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
                f"useful={r['useful_ratio']:.2f} peak/dev="
                f"{rec['bytes_per_device']['peak_est']/2**30:.1f}GiB",
            )
        )
    return rows
