#!/usr/bin/env python
"""Validate benchmark artifacts against their documented schemas.

    python scripts/check_bench_schema.py BENCH_eval.json BENCH_speed.json
    python scripts/check_bench_schema.py --full BENCH_eval.json ...

Exits non-zero (listing every problem) when an artifact has drifted from
the schema documented in docs/BENCH.md — the CI tripwire that keeps
BENCH_eval.json / BENCH_speed.json append-only contracts rather than
silently mutating shapes. ``--full`` additionally pins the checked-in
artifacts' coverage: the eval matrix must span every registered system x
env cell and the speed slice its three tracked families (use it for the
committed artifacts; CI smoke slices validate without it).

Thin CLI over `repro.bench.schema`, loaded straight from its file so this
runs in dependency-less environments (the lint job has no jax; importing
the `repro.bench` package would pull it in).
"""
import importlib.util
import pathlib
import sys

_SCHEMA_PY = (
    pathlib.Path(__file__).resolve().parent.parent
    / "src" / "repro" / "bench" / "schema.py"
)
_spec = importlib.util.spec_from_file_location("repro_bench_schema", _SCHEMA_PY)
_schema = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_schema)


def main(paths):
    full = "--full" in paths
    paths = [p for p in paths if p != "--full"]
    if not paths:
        print(
            "usage: check_bench_schema.py [--full] ARTIFACT.json "
            "[ARTIFACT.json ...]"
        )
        return 2
    failed = False
    for path in paths:
        errs = _schema.validate_path(path, full=full)
        if errs:
            failed = True
            print(f"{path}: {len(errs)} schema problem(s)")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"{path}: schema OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
