#!/usr/bin/env python
"""Check that markdown links in the given files resolve.

    python scripts/check_md_links.py README.md docs/*.md

Dependency-less (runs in the CI docs job with no installs): every
relative link target must exist on disk, and every in-repo ``#anchor``
must match a heading in the target file (GitHub's slug rules, minus the
exotic cases). External ``http(s)``/``mailto`` links are recorded but not
fetched — CI must not flake on the network.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — skips images' leading ! via the (?<!\!) guard is not
# needed: image targets should resolve too, so match them as well
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
_CODE_FENCE = re.compile(r"```.*?```", re.S)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading (lowercase, spaces -> dashes)."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    """The set of heading anchors a markdown file exposes."""
    text = _CODE_FENCE.sub("", path.read_text())
    return {github_slug(h) for h in _HEADING.findall(text)}


def check_file(path: pathlib.Path) -> list:
    """All broken links in one markdown file (empty when clean)."""
    problems = []
    text = _CODE_FENCE.sub("", path.read_text())
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        dest, _, anchor = target.partition("#")
        dest_path = (path.parent / dest).resolve() if dest else path.resolve()
        if not dest_path.exists():
            problems.append(f"{path}: broken link -> {target}")
            continue
        if anchor and dest_path.suffix == ".md":
            if github_slug(anchor) not in anchors_of(dest_path):
                problems.append(f"{path}: missing anchor -> {target}")
    return problems


def main(paths) -> int:
    """CLI entry point: exit non-zero when any link is broken."""
    if not paths:
        print("usage: check_md_links.py FILE.md [FILE.md ...]")
        return 2
    problems = []
    for p in paths:
        problems.extend(check_file(pathlib.Path(p)))
    for prob in problems:
        print(prob)
    if not problems:
        print(f"{len(paths)} file(s): all links resolve")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
