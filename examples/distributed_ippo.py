"""Distributed on-policy training: IPPO on spread, then the sharded
MADQN executor scale-out (the paper's num_executors experiment) — run in a
subprocess so the host platform can expose 4 devices.

  PYTHONPATH=src python examples/distributed_ippo.py
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro.envs import Spread
from repro.systems.onpolicy import PPOConfig, make_ippo

print("== IPPO (fused rollout+update, 16 envs) ==")
env = Spread(num_agents=3, horizon=25)
system = make_ippo(env, PPOConfig(rollout_len=64, epochs=2, num_minibatches=2))
train, metrics = system["train"](jax.random.key(0), num_updates=120, num_envs=16)
r = np.asarray(metrics["reward"])
print(f"reward/step: first10={r[:10].mean():.3f} last10={r[-10:].mean():.3f}")

print("== sharded executors (4 devices via shard_map) ==")
code = """
import jax, numpy as np
from repro.envs import Spread
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig
from repro.core.system import train_distributed
from repro.launch.mesh import make_auto_mesh

mesh = make_auto_mesh((4,), ("data",))
cfg = OffPolicyConfig(buffer_capacity=20000, min_replay=500, batch_size=64,
                      distributed_axis="data")
params, metrics = train_distributed(make_madqn(Spread(num_agents=3), cfg),
                                    jax.random.key(0), 1500, 8, mesh)
print("per-executor mean reward:", np.round(np.asarray(metrics["reward"]).ravel(), 3))
"""
env_vars = dict(os.environ)
env_vars["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
env_vars["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                   env=env_vars, text=True)
sys.exit(r.returncode)
