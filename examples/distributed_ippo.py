"""Distributed on-policy training: IPPO on spread through the unified
System runners — fused Anakin first, then the sharded executor scale-out
(the paper's num_executors experiment, now available to the on-policy
family too) — run in a subprocess so the host platform can expose 4
devices.

  PYTHONPATH=src python examples/distributed_ippo.py
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro.core.system import train_anakin
from repro.envs import make_env
from repro.systems import make_system

print("== IPPO (fused rollout+update, 16 envs) ==")
env = make_env("spread", num_agents=3, horizon=25)
system = make_system("ippo", env, rollout_len=64, epochs=2, num_minibatches=2)
st, metrics = train_anakin(system, jax.random.key(0), 120 * 64, num_envs=16)
r = np.asarray(metrics["reward"])
k = max(len(r) // 10, 1)
print(f"reward/step: first10%={r[:k].mean():.3f} last10%={r[-k:].mean():.3f}")

print("== sharded IPPO executors (4 devices via shard_map) ==")
code = """
import jax, numpy as np
from repro.envs import make_env
from repro.systems import make_system
from repro.core.system import train_distributed
from repro.launch.mesh import make_auto_mesh

mesh = make_auto_mesh((4,), ("data",))
system = make_system("ippo", make_env("spread", num_agents=3),
                     distributed_axis="data",
                     rollout_len=64, epochs=2, num_minibatches=2)
params, metrics = train_distributed(system, jax.random.key(0), 1500, 8, mesh)
print("per-executor mean reward:", np.round(np.asarray(metrics["reward"]).ravel(), 3))
"""
env_vars = dict(os.environ)
env_vars["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
env_vars["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                   env=env_vars, text=True)
sys.exit(r.returncode)
