"""Paper Fig. 4 (top): DIAL communication on the switch riddle.

Trains recurrent Q-agents with the differentiable channel, then the
no-communication ablation, and prints the evaluation returns (hard channel,
decentralised execution).

  PYTHONPATH=src python examples/switch_game_dial.py [--updates 800]
"""
import argparse

import jax
import numpy as np

from repro.envs import SwitchGame
from repro.systems.dial import DialConfig, train_dial

p = argparse.ArgumentParser()
p.add_argument("--updates", type=int, default=800)
p.add_argument("--agents", type=int, default=3)
args = p.parse_args()

env = SwitchGame(num_agents=args.agents)
for use_comm in (True, False):
    name = "DIAL (learned channel)" if use_comm else "no communication"
    cfg = DialConfig(use_comm=use_comm, batch_episodes=32)
    train, metrics, system = train_dial(env, cfg, jax.random.key(0), args.updates)
    r = np.asarray(metrics["return"])
    ev = float(system["evaluate"](train, jax.random.key(99), batch=256))
    print(f"{name:24s} train_return(last 50): {r[-50:].mean():+.3f}   "
          f"eval_return (hard bits): {ev:+.3f}")
