"""Paper Fig. 4 (top): DIAL communication on the switch riddle.

Trains recurrent Q-agents with the differentiable channel through the
unified Anakin runner, then the no-communication ablation, and prints the
fused greedy-evaluator returns (hard channel, decentralised execution —
`repro.eval.evaluate` with `training=False` thresholds the DRU).

  PYTHONPATH=src python examples/switch_game_dial.py [--updates 800]
"""
import argparse

import jax
import numpy as np

from repro.core.system import train_anakin
from repro.envs import SwitchGame
from repro.eval import evaluate
from repro.systems.dial import DialConfig, make_dial

p = argparse.ArgumentParser()
p.add_argument("--updates", type=int, default=800)
p.add_argument("--agents", type=int, default=3)
args = p.parse_args()

env = SwitchGame(num_agents=args.agents)
rollout_len = env.horizon  # one episode per env per update (DialConfig default)
for use_comm in (True, False):
    name = "DIAL (learned channel)" if use_comm else "no communication"
    system = make_dial(env, DialConfig(use_comm=use_comm))
    st, metrics = train_anakin(
        system, jax.random.key(0), args.updates * rollout_len, num_envs=32
    )
    r = np.asarray(metrics["reward"]).reshape(args.updates, rollout_len)
    ev = evaluate(system, st.train, jax.random.key(99), num_episodes=256, num_envs=64)
    print(f"{name:24s} train_reward/step(last 50 updates): "
          f"{r[-50:].mean():+.3f}   "
          f"eval_return (hard bits): {float(np.asarray(ev.episode_return).mean()):+.3f}")
