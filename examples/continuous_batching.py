"""Serve a small model with batched requests (deliverable-b serving driver).

Continuous batching: 8 requests with ragged prompt lengths stream through a
2-slot engine; slots are refilled as requests finish. Output parity with
sequential generation is asserted for one request.

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

cfg = get_smoke_config("internlm2-1.8b")
params = M.init_model(jax.random.key(0), cfg)
rng = np.random.default_rng(0)

engine = ServingEngine(cfg, params, max_slots=2, prompt_capacity=24, max_new_tokens=8)
prompts = [
    rng.integers(0, cfg.vocab, (int(L),)).astype(np.int32)
    for L in rng.integers(6, 20, size=8)
]
for i, p in enumerate(prompts):
    engine.submit(Request(uid=i, prompt=p, max_new_tokens=8))

t0 = time.time()
finished = engine.run_until_drained()
dt = time.time() - t0
total_tokens = sum(len(r.output) for r in finished)
print(f"served {len(finished)} requests / {total_tokens} tokens "
      f"in {dt:.1f}s on 2 slots")
for r in sorted(finished, key=lambda r: r.uid)[:4]:
    print(f"  req {r.uid} (prompt {len(r.prompt):2d} toks) -> {r.output}")

# parity with a sequential single-stream run
import jax.numpy as jnp


def sequential_generate(cfg, params, prompt, n):
    batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
    logits, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, max_len=len(prompt) + n + 4)
    )(params, batch)
    out = [int(jnp.argmax(logits[0, -1]))]
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    for _ in range(n - 1):
        logits, cache = step(params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


ref = sequential_generate(cfg, params, prompts[0], 8)
got = next(r.output for r in finished if r.uid == 0)
assert got == ref, (got, ref)
print("parity with sequential generation: OK")
