"""Quickstart — the paper's Block 1 + Block 2 in JAX-Mava form.

Builds a MADQN system, shows the faithful executor-environment loop, then
launches the same system fused (Anakin) — the two-line scale-up that
replaces the Launchpad program graph.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.system import run_environment_loop, train_anakin
from repro.envs import MatrixGame
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig

# ---- Block 2 analogue: build the system (env factory + network config) ----
env = MatrixGame(horizon=10)
system = make_madqn(
    env,
    OffPolicyConfig(
        hidden_sizes=(64, 64),
        buffer_capacity=5_000,
        min_replay=100,
        batch_size=32,
        eps_decay_steps=2_000,
        learning_rate=1e-3,
    ),
)

# ---- Block 1 analogue: the executor-environment loop (faithful, python) ----
print("== faithful environment loop (3 episodes) ==")
train_state, buffer_state, returns = run_environment_loop(
    system, jax.random.key(0), num_episodes=3
)
print("episode returns:", [round(r, 1) for r in returns])

# ---- the JAX rewrite: same system, fused + vectorised ----
print("== anakin: scan(3000) x vmap(8 envs), one jit ==")
st, metrics = train_anakin(system, jax.random.key(0), num_iterations=3000, num_envs=8)
r = np.asarray(metrics["reward"])
print(f"mean reward/step: first200={r[:200].mean():.2f}  last200={r[-200:].mean():.2f}")
assert r[-200:].mean() > r[:200].mean(), "system failed to learn"
print("learned the climbing game.")
