"""Quickstart — the paper's Block 1 + Block 2 in JAX-Mava form.

Builds a system from the registry (`make_system` — any of the nine
algorithm families behind one constructor), shows the faithful
executor-environment loop, then launches the *same* system fused
(Anakin) — the two-line scale-up that replaces the Launchpad program
graph.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.system import run_environment_loop, train_anakin
from repro.envs import make_env
from repro.systems import make_system

# ---- Block 2 analogue: build the system from the registry ----
env = make_env("matrix_game", horizon=10)
system = make_system(
    "madqn",
    env,
    hidden_sizes=(64, 64),
    buffer_capacity=5_000,
    min_replay=100,
    batch_size=32,
    eps_decay_steps=2_000,
    learning_rate=1e-3,
)

# ---- Block 1 analogue: the executor-environment loop (faithful, python) ----
print("== faithful environment loop (3 episodes) ==")
train_state, buffer_state, ev = run_environment_loop(
    system, jax.random.key(0), num_episodes=3
)
print("team episode returns:", [round(float(r), 1) for r in ev.episode_return])

# ---- the JAX rewrite: same system, fused + vectorised, eval in the jit ----
print("== anakin: scan(3000) x vmap(8 envs) + greedy eval every 1000, one jit ==")
st, metrics, evals = train_anakin(
    system, jax.random.key(0), num_iterations=3000, num_envs=8,
    eval_every=1000, eval_episodes=16,
)
r = np.asarray(metrics["reward"])
print(f"mean reward/step: first200={r[:200].mean():.2f}  last200={r[-200:].mean():.2f}")
print("greedy eval return per 1000 iters:",
      np.asarray(evals.episode_return).mean(axis=-1).round(2))
assert r[-200:].mean() > r[:200].mean(), "system failed to learn"
print("learned the climbing game.")

# ---- the same two lines work for the on-policy flagship ----
print("== same runner, flagship system: ippo on the same env ==")
ippo = make_system("ippo", env, rollout_len=32, num_minibatches=2)
st, metrics = train_anakin(ippo, jax.random.key(0), num_iterations=3200, num_envs=8)
r = np.asarray(metrics["reward"])
print(f"ippo reward/step: first200={r[:200].mean():.2f}  last200={r[-200:].mean():.2f}")
