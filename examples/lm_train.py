"""End-to-end LM training driver (deliverable (b)): train a ~100M-param
model for a few hundred steps on the synthetic bigram corpus and assert the
loss drops toward the structural entropy floor.

  PYTHONPATH=src python examples/lm_train.py [--steps 200]

Uses a 4-layer/512-wide internlm2-family config (~40M params embedded,
~100M with vocab) — the largest that trains in reasonable time on CPU.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.tokens import SyntheticTokenDataset
from repro.launch.steps import make_train_step
from repro.models import model as M

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=200)
p.add_argument("--batch", type=int, default=16)
p.add_argument("--seq", type=int, default=128)
args = p.parse_args()

cfg = dataclasses.replace(
    get_smoke_config("internlm2-1.8b"),
    num_layers=4,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=2048,
    vocab=8192,
    attn_chunk=64,
    xent_chunk=64,
    name="internlm2-demo-100m",
)
print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")

params = M.init_model(jax.random.key(0), cfg)
opt, train_step = make_train_step(cfg, lr=1e-3)
opt_state = opt.init(params)
step = jax.jit(train_step, donate_argnums=(0, 1))

ds = SyntheticTokenDataset(cfg.vocab, args.seq, args.batch, seed=0, structure=0.85)
rng = np.random.default_rng(0)

losses = []
t0 = time.time()
for i in range(args.steps):
    host = ds.sample(rng)
    batch = {"tokens": jnp.asarray(host["tokens"]), "labels": jnp.asarray(host["labels"])}
    params, opt_state, metrics = step(params, opt_state, batch)
    losses.append(float(metrics["loss"]))
    if i % 20 == 0:
        print(f"step {i:4d}  loss {losses[-1]:.4f}  ({time.time()-t0:.0f}s)")

first, last = np.mean(losses[:10]), np.mean(losses[-10:])
print(f"\nloss: {first:.3f} -> {last:.3f}")
assert last < first - 1.0, "expected the model to learn the bigram structure"
print("learned the synthetic corpus structure.")
