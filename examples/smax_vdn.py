"""Paper Fig. 4 (bottom): value decomposition on a 3-marine battle.

VDN vs independent MADQN on smax-lite (the offline stand-in for SMAC 3m).

  PYTHONPATH=src python examples/smax_vdn.py [--iters 8000]
"""
import argparse

import jax
import numpy as np

from repro.core.system import train_anakin
from repro.envs import SmaxLite
from repro.systems.madqn import make_madqn
from repro.systems.offpolicy import OffPolicyConfig
from repro.systems.vdn import make_vdn

p = argparse.ArgumentParser()
p.add_argument("--iters", type=int, default=12000)
args = p.parse_args()

env = SmaxLite(num_agents=3)
cfg = OffPolicyConfig(
    buffer_capacity=50_000, min_replay=500, batch_size=64,
    eps_decay_steps=4_000, target_update_period=200, learning_rate=1e-3,
)
for maker, name in ((make_madqn, "independent MADQN"), (make_vdn, "VDN")):
    system = maker(env, cfg)
    st, metrics = train_anakin(system, jax.random.key(0), args.iters, num_envs=8)
    r = np.asarray(metrics["reward"])
    k = max(args.iters // 10, 1)
    print(f"{name:18s} reward/step first10%={r[:k].mean():.4f} "
          f"last10%={r[-k:].mean():.4f}")
